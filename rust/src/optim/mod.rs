//! Server-side optimizers over flat parameter vectors.
//!
//! The paper's experiments use plain SGD with per-method tuned learning
//! rates; momentum and Adam are provided for the finetuning-style figure
//! runs and the e2e LM driver.

/// A first-order optimizer over a flat parameter vector.
pub trait Optimizer: Send {
    fn name(&self) -> String;
    /// Apply one update given the aggregated gradient estimate.
    fn step(&mut self, params: &mut [f32], grad: &[f32]);
    fn lr(&self) -> f32;
    fn set_lr(&mut self, lr: f32);
}

/// Plain SGD: `x ← x − η g`.
pub struct Sgd {
    pub lr: f32,
}

impl Optimizer for Sgd {
    fn name(&self) -> String {
        format!("sgd(lr={})", self.lr)
    }
    fn step(&mut self, params: &mut [f32], grad: &[f32]) {
        crate::tensor::axpy(params, -self.lr, grad);
    }
    fn lr(&self) -> f32 {
        self.lr
    }
    fn set_lr(&mut self, lr: f32) {
        self.lr = lr;
    }
}

/// Heavy-ball momentum: `m ← β m + g; x ← x − η m`.
pub struct Momentum {
    pub lr: f32,
    pub beta: f32,
    m: Vec<f32>,
}

impl Momentum {
    pub fn new(lr: f32, beta: f32, d: usize) -> Self {
        Momentum { lr, beta, m: vec![0.0; d] }
    }
}

impl Optimizer for Momentum {
    fn name(&self) -> String {
        format!("momentum(lr={},beta={})", self.lr, self.beta)
    }
    fn step(&mut self, params: &mut [f32], grad: &[f32]) {
        for ((m, p), g) in self.m.iter_mut().zip(params.iter_mut()).zip(grad) {
            *m = self.beta * *m + *g;
            *p -= self.lr * *m;
        }
    }
    fn lr(&self) -> f32 {
        self.lr
    }
    fn set_lr(&mut self, lr: f32) {
        self.lr = lr;
    }
}

/// Adam (Kingma & Ba) with bias correction.
pub struct Adam {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    m: Vec<f32>,
    v: Vec<f32>,
    t: u64,
}

impl Adam {
    pub fn new(lr: f32, d: usize) -> Self {
        Adam { lr, beta1: 0.9, beta2: 0.999, eps: 1e-8, m: vec![0.0; d], v: vec![0.0; d], t: 0 }
    }
}

impl Optimizer for Adam {
    fn name(&self) -> String {
        format!("adam(lr={})", self.lr)
    }
    fn step(&mut self, params: &mut [f32], grad: &[f32]) {
        self.t += 1;
        let bc1 = 1.0 - self.beta1.powi(self.t as i32);
        let bc2 = 1.0 - self.beta2.powi(self.t as i32);
        for ((m, v), (p, g)) in self
            .m
            .iter_mut()
            .zip(self.v.iter_mut())
            .zip(params.iter_mut().zip(grad))
        {
            *m = self.beta1 * *m + (1.0 - self.beta1) * g;
            *v = self.beta2 * *v + (1.0 - self.beta2) * g * g;
            let mhat = *m / bc1;
            let vhat = *v / bc2;
            *p -= self.lr * mhat / (vhat.sqrt() + self.eps);
        }
    }
    fn lr(&self) -> f32 {
        self.lr
    }
    fn set_lr(&mut self, lr: f32) {
        self.lr = lr;
    }
}

/// Build an optimizer by name ("sgd" | "momentum" | "adam").
pub fn build(name: &str, lr: f32, d: usize) -> Box<dyn Optimizer> {
    match name {
        "sgd" => Box::new(Sgd { lr }),
        "momentum" => Box::new(Momentum::new(lr, 0.9, d)),
        "adam" => Box::new(Adam::new(lr, d)),
        other => panic!("unknown optimizer {other:?}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// minimize f(x) = 0.5 Σ a_i x_i² with exact gradients
    fn quad_grad(x: &[f32], a: &[f32]) -> Vec<f32> {
        x.iter().zip(a).map(|(xi, ai)| ai * xi).collect()
    }

    fn run(opt: &mut dyn Optimizer, steps: usize) -> f64 {
        let a = [1.0f32, 4.0, 0.5, 2.0];
        let mut x = vec![1.0f32; 4];
        for _ in 0..steps {
            let g = quad_grad(&x, &a);
            opt.step(&mut x, &g);
        }
        crate::tensor::sq_norm(&x)
    }

    #[test]
    fn sgd_converges_on_quadratic() {
        let mut opt = Sgd { lr: 0.1 };
        assert!(run(&mut opt, 200) < 1e-6);
    }

    #[test]
    fn momentum_converges_on_quadratic() {
        let mut opt = Momentum::new(0.05, 0.9, 4);
        assert!(run(&mut opt, 300) < 1e-6);
    }

    #[test]
    fn adam_converges_on_quadratic() {
        let mut opt = Adam::new(0.05, 4);
        assert!(run(&mut opt, 800) < 1e-4);
    }

    #[test]
    fn sgd_step_exact() {
        let mut opt = Sgd { lr: 0.5 };
        let mut x = vec![1.0f32, 2.0];
        opt.step(&mut x, &[2.0, -2.0]);
        assert_eq!(x, vec![0.0, 3.0]);
    }

    #[test]
    fn build_by_name() {
        assert!(build("sgd", 0.1, 4).name().starts_with("sgd"));
        assert!(build("momentum", 0.1, 4).name().starts_with("momentum"));
        assert!(build("adam", 0.1, 4).name().starts_with("adam"));
    }

    #[test]
    #[should_panic]
    fn build_unknown_panics() {
        build("lbfgs", 0.1, 4);
    }

    #[test]
    fn set_lr_roundtrip() {
        let mut o = Sgd { lr: 0.1 };
        o.set_lr(0.2);
        assert_eq!(o.lr(), 0.2);
    }
}
