//! PJRT runtime: load the AOT artifacts (`artifacts/*.hlo.txt` +
//! `metadata.json`, emitted once by `python/compile/aot.py`) and execute
//! them from the training hot path. Python never runs here.
//!
//! Pattern (see /opt/xla-example): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. Executables are compiled lazily and
//! cached per artifact name.
//!
//! NOTE: the `xla` crate's wrappers hold raw pointers and are `!Send`;
//! the runtime therefore lives on the thread that created it. Logical
//! workers share it sequentially (this testbed is single-core), and the
//! TCP cluster mode runs one runtime per worker *process*.

pub mod meta;

pub use meta::{ArtifactMeta, Dtype, Metadata, ModelMeta, ParamMeta, TensorSpec};

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::PathBuf;

use anyhow::{anyhow, bail, Context, Result};

/// Argument to an artifact execution.
pub enum ArgValue<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
}

impl ArgValue<'_> {
    fn len(&self) -> usize {
        match self {
            ArgValue::F32(v) => v.len(),
            ArgValue::I32(v) => v.len(),
        }
    }
    fn dtype(&self) -> Dtype {
        match self {
            ArgValue::F32(_) => Dtype::F32,
            ArgValue::I32(_) => Dtype::I32,
        }
    }
}

/// Output of an artifact execution.
#[derive(Clone, Debug)]
pub enum OutValue {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl OutValue {
    pub fn as_f32(&self) -> &[f32] {
        match self {
            OutValue::F32(v) => v,
            OutValue::I32(_) => panic!("expected f32 output"),
        }
    }
    pub fn as_i32(&self) -> &[i32] {
        match self {
            OutValue::I32(v) => v,
            OutValue::F32(_) => panic!("expected i32 output"),
        }
    }
    /// Scalar f32 convenience (loss outputs).
    pub fn scalar(&self) -> f32 {
        let v = self.as_f32();
        assert_eq!(v.len(), 1, "not a scalar");
        v[0]
    }
}

/// The PJRT-backed artifact executor.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub meta: Metadata,
    cache: RefCell<BTreeMap<String, xla::PjRtLoadedExecutable>>,
    /// executions per artifact (perf introspection)
    exec_counts: RefCell<BTreeMap<String, u64>>,
}

impl Runtime {
    /// Load metadata from the artifacts directory and stand up a CPU
    /// PJRT client. Compilation happens lazily per artifact.
    pub fn load(dir: impl Into<PathBuf>) -> Result<Self> {
        let dir = dir.into();
        let meta_path = dir.join("metadata.json");
        let text = std::fs::read_to_string(&meta_path)
            .with_context(|| format!("reading {} (run `make artifacts`)", meta_path.display()))?;
        let meta = Metadata::parse(&text).map_err(|e| anyhow!("parsing metadata: {e}"))?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime {
            client,
            dir,
            meta,
            cache: RefCell::new(BTreeMap::new()),
            exec_counts: RefCell::new(BTreeMap::new()),
        })
    }

    /// Load from the default `<repo>/artifacts` directory.
    pub fn load_default() -> Result<Self> {
        Self::load(crate::util::artifacts_dir())
    }

    /// Ensure an artifact is compiled (warms the cache).
    pub fn compile(&self, name: &str) -> Result<()> {
        if self.cache.borrow().contains_key(name) {
            return Ok(());
        }
        let art = self
            .meta
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact {name:?}"))?;
        let path = self.dir.join(&art.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        self.cache.borrow_mut().insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute an artifact with shape/dtype checking against metadata.
    pub fn exec(&self, name: &str, args: &[ArgValue]) -> Result<Vec<OutValue>> {
        let art = self
            .meta
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact {name:?}"))?
            .clone();
        if args.len() != art.inputs.len() {
            bail!("{name}: expected {} args, got {}", art.inputs.len(), args.len());
        }
        let mut literals = Vec::with_capacity(args.len());
        for (i, (arg, spec)) in args.iter().zip(&art.inputs).enumerate() {
            if arg.dtype() != spec.dtype {
                bail!("{name}: arg {i} dtype mismatch (expected {:?})", spec.dtype);
            }
            if arg.len() != spec.numel() {
                bail!(
                    "{name}: arg {i} has {} elements, expected {} (shape {:?})",
                    arg.len(),
                    spec.numel(),
                    spec.shape
                );
            }
            let dims: Vec<i64> = spec.shape.iter().map(|d| *d as i64).collect();
            let lit = match arg {
                ArgValue::F32(v) => xla::Literal::vec1(v),
                ArgValue::I32(v) => xla::Literal::vec1(v),
            };
            let lit = if spec.shape.len() == 1 { lit } else { lit.reshape(&dims)? };
            literals.push(lit);
        }
        self.compile(name)?;
        let cache = self.cache.borrow();
        let exe = cache.get(name).unwrap();
        let result = exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        drop(cache);
        *self.exec_counts.borrow_mut().entry(name.to_string()).or_insert(0) += 1;
        // aot.py lowers with return_tuple=True: always a tuple literal
        let parts = result.to_tuple()?;
        if parts.len() != art.outputs.len() {
            bail!("{name}: got {} outputs, expected {}", parts.len(), art.outputs.len());
        }
        let mut outs = Vec::with_capacity(parts.len());
        for (lit, spec) in parts.into_iter().zip(&art.outputs) {
            let out = match spec.dtype {
                Dtype::F32 => OutValue::F32(lit.to_vec::<f32>()?),
                Dtype::I32 => OutValue::I32(lit.to_vec::<i32>()?),
            };
            outs.push(out);
        }
        Ok(outs)
    }

    /// Gradient step helper: `(loss, grad)` for a model artifact.
    pub fn grad_step(
        &self,
        model: &ModelMeta,
        params: &[f32],
        x: &ArgValue,
        y: &[i32],
    ) -> Result<(f32, Vec<f32>)> {
        let outs =
            self.exec(&model.grad, &[ArgValue::F32(params), reborrow(x), ArgValue::I32(y)])?;
        let loss = outs[0].scalar();
        let grad = match &outs[1] {
            OutValue::F32(g) => g.clone(),
            _ => bail!("grad output not f32"),
        };
        Ok((loss, grad))
    }

    /// Eval helper: `(loss, n_correct)`.
    pub fn eval_step(
        &self,
        model: &ModelMeta,
        params: &[f32],
        x: &ArgValue,
        y: &[i32],
    ) -> Result<(f32, f32)> {
        let outs =
            self.exec(&model.eval, &[ArgValue::F32(params), reborrow(x), ArgValue::I32(y)])?;
        Ok((outs[0].scalar(), outs[1].scalar()))
    }

    /// Segment-stats helper (the L1 Pallas path of Alg. 3):
    /// returns `(seg_sq, perm)` from the model's `frac_pm` stats artifact.
    pub fn seg_stats(
        &self,
        model: &ModelMeta,
        frac_pm: u32,
        grad: &[f32],
    ) -> Result<(Vec<f32>, Vec<u32>)> {
        let art_name = model
            .segstats
            .get(&frac_pm)
            .ok_or_else(|| anyhow!("model {} has no segstats for pm{}", model.name, frac_pm))?;
        let outs = self.exec(art_name, &[ArgValue::F32(grad)])?;
        let seg_sq = outs[0].as_f32().to_vec();
        let perm: Vec<u32> = outs[1].as_i32().iter().map(|i| *i as u32).collect();
        Ok((seg_sq, perm))
    }

    /// Fused gradient + segment-stats step (one PJRT dispatch — the
    /// Alg. 3 perf path, see EXPERIMENTS.md §Perf):
    /// `(loss, grad, seg_sq, perm)`.
    pub fn grad_stats_step(
        &self,
        model: &ModelMeta,
        frac_pm: u32,
        params: &[f32],
        x: &ArgValue,
        y: &[i32],
    ) -> Result<(f32, Vec<f32>, Vec<f32>, Vec<u32>)> {
        let art_name = model
            .gradstats
            .get(&frac_pm)
            .ok_or_else(|| anyhow!("model {} has no gradstats for pm{}", model.name, frac_pm))?;
        let outs =
            self.exec(art_name, &[ArgValue::F32(params), reborrow(x), ArgValue::I32(y)])?;
        let loss = outs[0].scalar();
        let grad = outs[1].as_f32().to_vec();
        let seg_sq = outs[2].as_f32().to_vec();
        let perm: Vec<u32> = outs[3].as_i32().iter().map(|i| *i as u32).collect();
        Ok((loss, grad, seg_sq, perm))
    }

    /// How many times each artifact has executed (perf logging).
    pub fn exec_counts(&self) -> BTreeMap<String, u64> {
        self.exec_counts.borrow().clone()
    }
}

/// Re-borrow an [`ArgValue`] (they are cheap views).
pub fn reborrow<'a>(x: &'a ArgValue<'a>) -> ArgValue<'a> {
    match x {
        ArgValue::F32(v) => ArgValue::F32(v),
        ArgValue::I32(v) => ArgValue::I32(v),
    }
}
