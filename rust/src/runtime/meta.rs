//! Typed view of `artifacts/metadata.json` (emitted by
//! `python/compile/aot.py`), parsed with the in-tree JSON parser.

use std::collections::BTreeMap;

use crate::util::json::Json;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    fn parse(s: &str) -> Result<Dtype, String> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            other => Err(format!("unknown dtype {other:?}")),
        }
    }
}

#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub dtype: Dtype,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn parse(j: &Json) -> Result<TensorSpec, String> {
        let dtype = Dtype::parse(j.req("dtype").as_str().ok_or("dtype not a string")?)?;
        let shape = j
            .req("shape")
            .as_arr()
            .ok_or("shape not an array")?
            .iter()
            .map(|v| v.as_usize().ok_or_else(|| "bad dim".to_string()))
            .collect::<Result<Vec<_>, _>>()?;
        Ok(TensorSpec { dtype, shape })
    }
}

#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    pub kind: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub model: Option<String>,
    pub seg_size: Option<usize>,
    pub n_segs: Option<usize>,
    pub frac_pm: Option<u32>,
}

#[derive(Clone, Debug)]
pub struct ParamMeta {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub numel: usize,
    /// "normal" | "zeros" | "ones"
    pub init: String,
    pub std: f32,
}

#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub name: String,
    /// "tx" | "lm" | "cnn"
    pub kind: String,
    pub param_count: usize,
    pub batch: usize,
    /// tx/lm only
    pub seq_len: usize,
    pub vocab: usize,
    /// cnn only
    pub image: usize,
    pub in_channels: usize,
    pub n_classes: usize,
    pub grad: String,
    pub eval: String,
    /// frac (per-mille) -> segstats artifact name
    pub segstats: BTreeMap<u32, String>,
    /// frac (per-mille) -> fused grad+stats artifact name (perf path)
    pub gradstats: BTreeMap<u32, String>,
    pub params: Vec<ParamMeta>,
}

impl ModelMeta {
    pub fn is_lm(&self) -> bool {
        self.kind == "lm"
    }
    pub fn is_image(&self) -> bool {
        self.kind == "cnn"
    }

    /// Number of label entries per batch (LM labels are per-token).
    pub fn y_len(&self) -> usize {
        if self.is_lm() {
            self.batch * self.seq_len
        } else {
            self.batch
        }
    }

    /// Number of x entries per batch.
    pub fn x_len(&self) -> usize {
        if self.is_image() {
            self.batch * self.image * self.image * self.in_channels
        } else {
            self.batch * self.seq_len
        }
    }

    /// Initialize a flat parameter vector per the build-time spec
    /// (mirrors `python/compile/model.py::init_flat` semantics; the exact
    /// draws differ — only the distribution matters).
    pub fn init_params(&self, seed: u64) -> Vec<f32> {
        let mut out = vec![0.0f32; self.param_count];
        let mut rng = crate::tensor::Rng::for_stream(seed, 0x1417, 0);
        for p in &self.params {
            let dst = &mut out[p.offset..p.offset + p.numel];
            match p.init.as_str() {
                "normal" => rng.fill_normal(dst, p.std),
                "ones" => dst.fill(1.0),
                _ => dst.fill(0.0),
            }
        }
        out
    }

    /// Segment size for a per-mille sparsification fraction.
    pub fn seg_size(&self, frac_pm: u32) -> usize {
        ((self.param_count as u64 * frac_pm as u64 + 500) / 1000).max(1) as usize
    }
}

#[derive(Clone, Debug)]
pub struct Metadata {
    pub elemwise_chunk: usize,
    pub models: BTreeMap<String, ModelMeta>,
    pub artifacts: BTreeMap<String, ArtifactMeta>,
}

impl Metadata {
    pub fn parse(text: &str) -> Result<Metadata, String> {
        let j = Json::parse(text).map_err(|e| e.to_string())?;
        let elemwise_chunk = j.req("elemwise_chunk").as_usize().ok_or("bad elemwise_chunk")?;

        let mut artifacts = BTreeMap::new();
        for (name, a) in j.req("artifacts").as_obj().ok_or("artifacts not an object")? {
            let inputs = a
                .req("inputs")
                .as_arr()
                .ok_or("inputs not an array")?
                .iter()
                .map(TensorSpec::parse)
                .collect::<Result<Vec<_>, _>>()?;
            let outputs = a
                .req("outputs")
                .as_arr()
                .ok_or("outputs not an array")?
                .iter()
                .map(TensorSpec::parse)
                .collect::<Result<Vec<_>, _>>()?;
            artifacts.insert(
                name.clone(),
                ArtifactMeta {
                    name: name.clone(),
                    file: a.req("file").as_str().ok_or("bad file")?.to_string(),
                    kind: a.req("kind").as_str().ok_or("bad kind")?.to_string(),
                    inputs,
                    outputs,
                    model: a.get("model").and_then(|v| v.as_str()).map(String::from),
                    seg_size: a.get("seg_size").and_then(|v| v.as_usize()),
                    n_segs: a.get("n_segs").and_then(|v| v.as_usize()),
                    frac_pm: a.get("frac_pm").and_then(|v| v.as_usize()).map(|v| v as u32),
                },
            );
        }

        let mut models = BTreeMap::new();
        for (name, m) in j.req("models").as_obj().ok_or("models not an object")? {
            let mut segstats = BTreeMap::new();
            if let Some(ss) = m.get("segstats").and_then(|v| v.as_obj()) {
                for (pm, art) in ss {
                    let pm: u32 = pm.parse().map_err(|_| "bad frac_pm key")?;
                    segstats.insert(pm, art.as_str().ok_or("bad segstats entry")?.to_string());
                }
            }
            let mut gradstats = BTreeMap::new();
            if let Some(gs) = m.get("gradstats").and_then(|v| v.as_obj()) {
                for (pm, art) in gs {
                    let pm: u32 = pm.parse().map_err(|_| "bad frac_pm key")?;
                    gradstats.insert(pm, art.as_str().ok_or("bad gradstats entry")?.to_string());
                }
            }
            let params = m
                .req("params")
                .as_arr()
                .ok_or("params not an array")?
                .iter()
                .map(|p| {
                    Ok::<_, String>(ParamMeta {
                        name: p.req("name").as_str().ok_or("bad param name")?.to_string(),
                        shape: p
                            .req("shape")
                            .as_arr()
                            .ok_or("bad param shape")?
                            .iter()
                            .map(|v| v.as_usize().ok_or_else(|| "bad dim".to_string()))
                            .collect::<Result<Vec<_>, _>>()?,
                        offset: p.req("offset").as_usize().ok_or("bad offset")?,
                        numel: p.req("numel").as_usize().ok_or("bad numel")?,
                        init: p.req("init").as_str().ok_or("bad init")?.to_string(),
                        std: p.req("std").as_f64().ok_or("bad std")? as f32,
                    })
                })
                .collect::<Result<Vec<_>, _>>()?;
            let get_usize = |key: &str| m.get(key).and_then(|v| v.as_usize()).unwrap_or(0);
            models.insert(
                name.clone(),
                ModelMeta {
                    name: name.clone(),
                    kind: m.req("kind").as_str().ok_or("bad model kind")?.to_string(),
                    param_count: m.req("param_count").as_usize().ok_or("bad param_count")?,
                    batch: m.req("batch").as_usize().ok_or("bad batch")?,
                    seq_len: get_usize("seq_len"),
                    vocab: get_usize("vocab"),
                    image: get_usize("image"),
                    in_channels: get_usize("in_channels"),
                    n_classes: get_usize("n_classes"),
                    grad: m.req("grad").as_str().ok_or("bad grad")?.to_string(),
                    eval: m.req("eval").as_str().ok_or("bad eval")?.to_string(),
                    segstats,
                    gradstats,
                    params,
                },
            );
        }
        Ok(Metadata { elemwise_chunk, models, artifacts })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "elemwise_chunk": 1024,
      "artifacts": {
        "m_grad": {"file": "m_grad.hlo.txt", "kind": "grad", "model": "m",
          "param_count": 6,
          "inputs": [{"dtype": "f32", "shape": [6]},
                     {"dtype": "i32", "shape": [2, 3]},
                     {"dtype": "i32", "shape": [2]}],
          "outputs": [{"dtype": "f32", "shape": []},
                      {"dtype": "f32", "shape": [6]}]},
        "m_ss": {"file": "m_ss.hlo.txt", "kind": "segstats", "model": "m",
          "seg_size": 2, "n_segs": 3, "frac_pm": 333,
          "inputs": [{"dtype": "f32", "shape": [6]}],
          "outputs": [{"dtype": "f32", "shape": [3]}, {"dtype": "i32", "shape": [6]}]}
      },
      "models": {
        "m": {"kind": "tx", "param_count": 6, "batch": 2, "seq_len": 3,
          "vocab": 256, "n_classes": 2, "grad": "m_grad", "eval": "m_grad",
          "segstats": {"333": "m_ss"},
          "params": [
            {"name": "a", "shape": [2, 2], "offset": 0, "numel": 4, "init": "normal", "std": 0.5},
            {"name": "b", "shape": [2], "offset": 4, "numel": 2, "init": "ones", "std": 0.0}
          ]}
      }
    }"#;

    #[test]
    fn parses_sample() {
        let meta = Metadata::parse(SAMPLE).unwrap();
        assert_eq!(meta.elemwise_chunk, 1024);
        let m = &meta.models["m"];
        assert_eq!(m.param_count, 6);
        assert_eq!(m.segstats[&333], "m_ss");
        assert_eq!(m.y_len(), 2);
        assert_eq!(m.x_len(), 6);
        let art = &meta.artifacts["m_grad"];
        assert_eq!(art.inputs[1].numel(), 6);
        assert_eq!(art.outputs[0].shape.len(), 0);
        assert_eq!(art.outputs[0].numel(), 1); // scalar
    }

    #[test]
    fn init_params_follows_spec() {
        let meta = Metadata::parse(SAMPLE).unwrap();
        let m = &meta.models["m"];
        let p = m.init_params(7);
        assert_eq!(p.len(), 6);
        // "ones" block
        assert_eq!(&p[4..6], &[1.0, 1.0]);
        // "normal" block is nonzero and bounded-ish
        assert!(p[..4].iter().any(|x| *x != 0.0));
        assert!(p[..4].iter().all(|x| x.abs() < 0.5 * 6.0));
        // deterministic
        assert_eq!(p, m.init_params(7));
        assert_ne!(p, m.init_params(8));
    }

    #[test]
    fn seg_size_rounding() {
        let meta = Metadata::parse(SAMPLE).unwrap();
        let m = &meta.models["m"];
        assert_eq!(m.seg_size(500), 3); // 6 * 0.5
        assert_eq!(m.seg_size(1), 1); // floor would be 0 → clamped
    }

    #[test]
    fn parse_real_metadata_if_present() {
        let path = crate::util::artifacts_dir().join("metadata.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let meta = Metadata::parse(&text).unwrap();
            assert!(meta.models.contains_key("tx-tiny"));
            let m = &meta.models["tx-tiny"];
            assert_eq!(m.param_count, 118658);
            assert_eq!(m.segstats.len(), 4);
            let p = m.init_params(1);
            assert_eq!(p.len(), m.param_count);
        }
    }
}
