//! # mlmc-dist
//!
//! Reproduction of *"Beyond Communication Overhead: A Multilevel Monte
//! Carlo Approach for Mitigating Compression Bias in Distributed
//! Learning"* (ICML 2025) as a three-layer rust + JAX/Pallas system:
//!
//! * **L3 (this crate)** — the distributed coordinator: leader/worker
//!   data-parallel SGD, the compressor library, the MLMC estimator
//!   (Alg. 2) and its adaptive variant (Alg. 3), error-feedback baselines,
//!   a bit-exact wire protocol, transports, metrics, config, CLI, and the
//!   figure-regeneration harness.
//! * **L2** — JAX models (`python/compile/model.py`) AOT-lowered to HLO
//!   text, loaded and executed here via PJRT ([`runtime`]).
//! * **L1** — Pallas kernels (`python/compile/kernels/`) fused into the
//!   L2 graphs (segment energies for Lemma 3.4, fixed-point / RTN
//!   quantizers).
//!
//! Python never runs on the training path: `make artifacts` emits
//! everything up front and the rust binary is self-contained afterwards.
//!
//! See `DESIGN.md` for the system inventory and the per-experiment index,
//! and `EXPERIMENTS.md` for measured results.

pub mod benchlib;
pub mod compress;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod ef;
pub mod engine;
pub mod figures;
pub mod metrics;
pub mod mlmc;
pub mod netsim;
pub mod optim;
pub mod runtime;
pub mod tensor;
pub mod testing;
pub mod train;
pub mod transport;
pub mod util;
pub mod wire;
