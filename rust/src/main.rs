//! `mlmc-dist` CLI — the launcher.
//!
//! ```text
//! mlmc-dist train [--config run.toml] [--key=value ...]
//! mlmc-dist figure <fig1|fig2|fig3|fig4|fig5|fig6|scenario|all> [--quick]
//! mlmc-dist validate [lem32|lem33|lem34|lem36|thm41|comm|all]
//! mlmc-dist info
//! mlmc-dist worker --addr H:P --id N ...   (TCP cluster worker)
//! mlmc-dist subagg --addr H:P --id G --leaf-addr H:P ...  (tree middle tier)
//! mlmc-dist leader --addr H:P ...          (TCP cluster leader)
//! ```

use anyhow::{anyhow, bail, Result};

use mlmc_dist::config::TrainConfig;
use mlmc_dist::runtime::Runtime;
use mlmc_dist::{figures, train, util};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &[String]) -> Result<()> {
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    match cmd {
        "train" => cmd_train(&args[1..]),
        "figure" => figures::cli(&args[1..]),
        "validate" => figures::validate::cli(&args[1..]),
        "info" => cmd_info(),
        "leader" => mlmc_dist::coordinator::cluster::leader_main(&args[1..]),
        "subagg" => mlmc_dist::coordinator::cluster::subagg_main(&args[1..]),
        "worker" => mlmc_dist::coordinator::cluster::worker_main(&args[1..]),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown command {other:?} (try `mlmc-dist help`)"),
    }
}

fn print_help() {
    println!(
        "mlmc-dist — MLMC compression for distributed learning (ICML 2025 reproduction)\n\n\
         commands:\n\
         \x20 train    [--config FILE] [--key=value ...]   run one training config\n\
         \x20 figure   <fig1..fig6|scenario|all> [--quick] regenerate a paper figure; `scenario`\n\
         \x20                                              sweeps policy x link (loss vs sim time)\n\
         \x20 validate [lem32|lem33|lem34|lem36|thm41|comm|all]  lemma/theorem checks\n\
         \x20 leader   --addr H:P [--key=value ...]        TCP cluster leader\n\
         \x20 subagg   --addr H:P --id G --leaf-addr H:P   tree middle tier: relays rounds to its\n\
         \x20                                              leaf slice, batches replies upward\n\
         \x20 worker   --addr H:P --id N [--key=value ...] TCP cluster worker\n\
         \x20 info                                         list artifacts/models\n\n\
         config keys: {}\n\n\
         round-engine keys (policy objects: rust/src/engine/policy.rs):\n\
         \x20 workers        1..=16777216 (2^24)             population size M; virtual-mode memory is\n\
         \x20                                               O(active participants), so sampled rounds\n\
         \x20                                               scale to millions of simulated workers\n\
         \x20 participation  full | quorum | sampled | adaptive   round-close policy; adaptive picks k\n\
         \x20                                               per round at the arrival-CDF elbow (virtual\n\
         \x20                                               clock; real-time TCP falls back to majority)\n\
         \x20 quorum         k (0 = majority)               proceed at k arrivals; late msgs applied next round\n\
         \x20 sample_frac    (0,1]                          client fraction for participation=sampled\n\
         \x20 staleness      damp | full | drop | exp       stale Fresh-gradient weighting (EF21-family\n\
         \x20                                               increments always apply at full weight)\n\
         \x20 stale_decay    (0,1)                          geometric decay for staleness=exp\n\
         \x20 link           datacenter | edge | hetero | hetero-compute   netsim cost-model preset\n\
         \x20 straggler      seconds                        mean seeded straggler delay (0 = off)\n\
         \x20 compute        seconds                        base per-step grad-compute time (0 = preset default)\n\
         \x20 compute_spread factor >= 1                    per-worker compute slowdown spread (needs compute > 0)\n\n\
         recovery keys (real-time TCP rounds):\n\
         \x20 round_timeout  seconds (0 = wait forever)     deadline before resend requests go out\n\
         \x20 resend_max     n                              resend attempts before a reply is given up\n\
         \x20 exclude_after  n (0 = never)                  consecutive missed rounds before exclusion\n\
         \x20 readmit_every  n (0 = never)                  probe an excluded worker every n rounds\n\n\
         topology keys (hierarchical aggregation tree):\n\
         \x20 topology       star | tree                    flat star (default) or a sub-aggregator\n\
         \x20                                               tier: leader fan-in drops from M to ~sqrt(M)\n\
         \x20 fanout         leaves per group (0 = auto)    auto picks the smallest f with f*f >= M\n\
         \x20 replication    r >= 1 (tree only)             coded leaves: r replicas per shard, first\n\
         \x20                                               on-time reply wins (sim + local tree runs)\n",
        [
            "model", "method", "workers", "steps", "lr", "seed", "frac_pm",
            "quant_bits", "eval_every", "eval_batches", "transport",
            "optimizer", "momentum_beta", "dirichlet_alpha", "use_l1_stats",
            "shard_size", "threads", "participation", "quorum", "sample_frac",
            "staleness", "stale_decay", "link", "straggler", "compute",
            "compute_spread", "round_timeout", "resend_max", "exclude_after",
            "readmit_every", "topology", "fanout", "replication", "tag",
        ]
        .join(", ")
    );
}

/// Parse `--config FILE` plus `--key=value` overrides.
pub fn parse_cfg(args: &[String]) -> Result<TrainConfig> {
    let mut cfg = TrainConfig::default();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if a == "--config" {
            let path = args.get(i + 1).ok_or_else(|| anyhow!("--config needs a path"))?;
            let text = std::fs::read_to_string(path)?;
            cfg = TrainConfig::from_toml(&text).map_err(|e| anyhow!(e))?;
            i += 2;
            continue;
        }
        let rest = a
            .strip_prefix("--")
            .ok_or_else(|| anyhow!("expected --key=value, got {a:?}"))?;
        let (k, v) = rest
            .split_once('=')
            .ok_or_else(|| anyhow!("expected --key=value, got {a:?}"))?;
        cfg.set(k, v).map_err(|e| anyhow!(e))?;
        i += 1;
    }
    cfg.validate().map_err(|e| anyhow!(e))?;
    Ok(cfg)
}

fn cmd_train(args: &[String]) -> Result<()> {
    let cfg = parse_cfg(args)?;
    let rt = Runtime::load_default()?;
    let csv = util::results_dir().join(format!("train_{}.csv", cfg.run_id()));
    println!("run {}: model={} method={} M={} steps={} lr={}",
        cfg.run_id(), cfg.model, cfg.method, cfg.workers, cfg.steps, cfg.lr);
    println!("legend: {}", mlmc_dist::coordinator::scenario_legend(&cfg));
    // repolint: allow(wall_clock) — progress logging only.
    let t = std::time::Instant::now();
    let r = train::run_with_csv(&rt, &cfg, Some(&csv))?;
    let (el, ea) = r
        .curve
        .points
        .iter()
        .rev()
        .find(|p| !p.eval_acc.is_nan())
        .map(|p| (p.eval_loss, p.eval_acc))
        .unwrap_or((f64::NAN, f64::NAN));
    println!(
        "done in {:.1}s: codec={} final_train_loss={:.4} eval_loss={:.4} eval_acc={:.4} \
         bits={} sim_time={:.3}s",
        t.elapsed().as_secs_f64(),
        r.codec_name,
        r.curve.tail_loss(5),
        el,
        ea,
        util::fmt_bits(r.total_bits),
        r.sim_time_s
    );
    println!("curve: {}", csv.display());
    Ok(())
}

fn cmd_info() -> Result<()> {
    let rt = Runtime::load_default()?;
    println!("artifacts dir: {}", util::artifacts_dir().display());
    println!("\nmodels:");
    for (name, m) in &rt.meta.models {
        println!(
            "  {:<10} kind={:<4} params={:>9}  batch={}  segstats@pm{:?}",
            name,
            m.kind,
            m.param_count,
            m.batch,
            m.segstats.keys().collect::<Vec<_>>()
        );
    }
    println!("\nartifacts:");
    for (name, a) in &rt.meta.artifacts {
        println!("  {:<28} kind={:<11} file={}", name, a.kind, a.file);
    }
    Ok(())
}
