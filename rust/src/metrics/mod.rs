//! Run metrics: CSV logging of training curves (the raw material for
//! every figure) and simple timing helpers.

pub mod ascii_plot;

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;
use std::time::Instant;

/// One logged training-curve point.
#[derive(Clone, Debug, PartialEq)]
pub struct Point {
    pub step: u64,
    /// cumulative uplink bits across all workers (figure x-axis)
    pub bits: u64,
    /// simulated wall-clock seconds (netsim virtual clock; NaN when the
    /// producer does not simulate time) — the figures' second x-axis
    pub sim_s: f64,
    pub train_loss: f64,
    pub eval_loss: f64,
    pub eval_acc: f64,
    pub wall_ms: f64,
}

/// In-memory training curve with optional CSV sink.
pub struct Curve {
    pub name: String,
    pub points: Vec<Point>,
    sink: Option<BufWriter<File>>,
    start: Instant,
}

impl Curve {
    pub fn new(name: impl Into<String>) -> Self {
        // repolint: allow(wall_clock) — diagnostics only: feeds the wall_ms
        // column, never a decision the replay depends on.
        Curve { name: name.into(), points: Vec::new(), sink: None, start: Instant::now() }
    }

    /// Also stream points to a CSV file (header written immediately).
    pub fn with_csv(name: impl Into<String>, path: &Path) -> std::io::Result<Self> {
        let mut c = Curve::new(name);
        let mut w = BufWriter::new(File::create(path)?);
        writeln!(w, "step,bits,sim_s,train_loss,eval_loss,eval_acc,wall_ms")?;
        c.sink = Some(w);
        Ok(c)
    }

    /// Log a point without a simulated timestamp (`sim_s = NaN`).
    pub fn log(&mut self, step: u64, bits: u64, train_loss: f64, eval_loss: f64, eval_acc: f64) {
        self.log_at(step, bits, f64::NAN, train_loss, eval_loss, eval_acc);
    }

    /// Log a point carrying the virtual clock's simulated wall-clock.
    pub fn log_at(
        &mut self,
        step: u64,
        bits: u64,
        sim_s: f64,
        train_loss: f64,
        eval_loss: f64,
        eval_acc: f64,
    ) {
        let p = Point {
            step,
            bits,
            sim_s,
            train_loss,
            eval_loss,
            eval_acc,
            wall_ms: self.start.elapsed().as_secs_f64() * 1e3,
        };
        if let Some(w) = &mut self.sink {
            let _ = writeln!(
                w,
                "{},{},{:.6},{:.6},{:.6},{:.6},{:.1}",
                p.step, p.bits, p.sim_s, p.train_loss, p.eval_loss, p.eval_acc, p.wall_ms
            );
        }
        self.points.push(p);
    }

    pub fn flush(&mut self) {
        if let Some(w) = &mut self.sink {
            let _ = w.flush();
        }
    }

    /// Best (max) eval accuracy seen.
    pub fn best_acc(&self) -> f64 {
        self.points.iter().map(|p| p.eval_acc).fold(0.0, f64::max)
    }

    /// Final logged train loss.
    pub fn final_loss(&self) -> f64 {
        self.points.last().map(|p| p.train_loss).unwrap_or(f64::NAN)
    }

    /// Bits needed to first reach an eval accuracy ≥ `target`
    /// (communication efficiency — the Fig. 1/4 summary statistic).
    pub fn bits_to_acc(&self, target: f64) -> Option<u64> {
        self.points.iter().find(|p| p.eval_acc >= target).map(|p| p.bits)
    }

    /// Mean train loss over the last `n` points (noise-robust endpoint).
    pub fn tail_loss(&self, n: usize) -> f64 {
        if self.points.is_empty() {
            return f64::NAN;
        }
        let tail = &self.points[self.points.len().saturating_sub(n)..];
        tail.iter().map(|p| p.train_loss).sum::<f64>() / tail.len() as f64
    }
}

/// Simple scoped timer.
pub struct Timer(Instant);

impl Timer {
    pub fn start() -> Self {
        // repolint: allow(wall_clock) — diagnostics-only scoped timer.
        Timer(Instant::now())
    }
    pub fn ms(&self) -> f64 {
        self.0.elapsed().as_secs_f64() * 1e3
    }
}

/// Mean/std over a slice (for seed-averaged figure series).
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    if xs.is_empty() {
        return (f64::NAN, f64::NAN);
    }
    let m = xs.iter().sum::<f64>() / xs.len() as f64;
    let var = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64;
    (m, var.sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn curve_accumulates_and_queries() {
        let mut c = Curve::new("t");
        c.log(0, 0, 2.0, 2.0, 0.5);
        c.log(10, 1000, 1.0, 1.2, 0.7);
        c.log(20, 2000, 0.5, 0.9, 0.9);
        assert_eq!(c.points.len(), 3);
        assert_eq!(c.best_acc(), 0.9);
        assert_eq!(c.final_loss(), 0.5);
        assert_eq!(c.bits_to_acc(0.65), Some(1000));
        assert_eq!(c.bits_to_acc(0.95), None);
        assert!((c.tail_loss(2) - 0.75).abs() < 1e-12);
    }

    #[test]
    fn csv_sink_writes() {
        let dir = std::env::temp_dir().join("mlmc_dist_test_metrics");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("curve.csv");
        {
            let mut c = Curve::with_csv("t", &path).unwrap();
            c.log_at(1, 64, 0.125, 1.5, 1.4, 0.6);
            c.flush();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("step,bits,sim_s"));
        assert!(text.lines().count() == 2);
        assert!(text.contains("1,64,0.125000,1.5"));
    }

    #[test]
    fn log_without_sim_time_records_nan() {
        let mut c = Curve::new("t");
        c.log(1, 10, 0.5, 0.4, 0.9);
        assert!(c.points[0].sim_s.is_nan());
        c.log_at(2, 20, 3.5, 0.4, 0.3, 0.95);
        assert_eq!(c.points[1].sim_s, 3.5);
    }

    #[test]
    fn mean_std_basic() {
        let (m, s) = mean_std(&[1.0, 3.0]);
        assert_eq!(m, 2.0);
        assert_eq!(s, 1.0);
        assert!(mean_std(&[]).0.is_nan());
    }

    #[test]
    fn timer_monotone() {
        let t = Timer::start();
        assert!(t.ms() >= 0.0);
    }
}
