//! Terminal line plots: render figure series as ASCII so
//! `mlmc-dist figure` output is readable without leaving the shell
//! (the CSVs remain the source of truth for real plotting).

/// One named series of (x, y) points.
pub struct Series<'a> {
    pub label: &'a str,
    pub points: Vec<(f64, f64)>,
}

const GLYPHS: &[char] = &['*', 'o', '+', 'x', '#', '@', '%', '&'];

/// Render series into a `width x height` character grid with axis
/// annotations. `log_x` plots x on a log10 scale (bits axes span decades).
pub fn render(series: &[Series], width: usize, height: usize, log_x: bool) -> String {
    let (width, height) = (width.max(16), height.max(4));
    let xf = |x: f64| if log_x { x.max(1.0).log10() } else { x };
    let mut xmin = f64::INFINITY;
    let mut xmax = f64::NEG_INFINITY;
    let mut ymin = f64::INFINITY;
    let mut ymax = f64::NEG_INFINITY;
    for s in series {
        for &(x, y) in &s.points {
            if !y.is_finite() {
                continue;
            }
            xmin = xmin.min(xf(x));
            xmax = xmax.max(xf(x));
            ymin = ymin.min(y);
            ymax = ymax.max(y);
        }
    }
    if !xmin.is_finite() || !ymin.is_finite() {
        return "(no finite points)\n".into();
    }
    if (xmax - xmin).abs() < 1e-12 {
        xmax = xmin + 1.0;
    }
    if (ymax - ymin).abs() < 1e-12 {
        ymax = ymin + 1.0;
    }
    let mut grid = vec![vec![' '; width]; height];
    for (si, s) in series.iter().enumerate() {
        let g = GLYPHS[si % GLYPHS.len()];
        for &(x, y) in &s.points {
            if !y.is_finite() {
                continue;
            }
            let cx = ((xf(x) - xmin) / (xmax - xmin) * (width - 1) as f64).round() as usize;
            let cy = ((y - ymin) / (ymax - ymin) * (height - 1) as f64).round() as usize;
            let row = height - 1 - cy.min(height - 1);
            grid[row][cx.min(width - 1)] = g;
        }
    }
    let mut out = String::new();
    for (i, row) in grid.iter().enumerate() {
        let ylabel = if i == 0 {
            format!("{ymax:>8.3} |")
        } else if i == height - 1 {
            format!("{ymin:>8.3} |")
        } else {
            format!("{:>8} |", "")
        };
        out.push_str(&ylabel);
        out.extend(row.iter());
        out.push('\n');
    }
    out.push_str(&format!("{:>9}+{}\n", "", "-".repeat(width)));
    let xl = if log_x { format!("1e{xmin:.1}") } else { format!("{xmin:.1}") };
    let xr = if log_x { format!("1e{xmax:.1}") } else { format!("{xmax:.1}") };
    out.push_str(&format!("{:>10}{}{:>w$}\n", xl, "", xr, w = width.saturating_sub(xl.len())));
    for (si, s) in series.iter().enumerate() {
        out.push_str(&format!("  {} {}\n", GLYPHS[si % GLYPHS.len()], s.label));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_two_series() {
        let s = vec![
            Series { label: "up", points: (0..20).map(|i| (i as f64, i as f64)).collect() },
            Series {
                label: "down",
                points: (0..20).map(|i| (i as f64, 20.0 - i as f64)).collect(),
            },
        ];
        let out = render(&s, 40, 10, false);
        assert!(out.contains('*'));
        assert!(out.contains('o'));
        assert!(out.contains("up"));
        assert!(out.contains("down"));
        assert!(out.lines().count() >= 12);
    }

    #[test]
    fn handles_empty_and_nan() {
        let s = vec![Series { label: "nan", points: vec![(1.0, f64::NAN)] }];
        assert!(render(&s, 30, 8, false).contains("no finite points"));
        let s = vec![Series { label: "one", points: vec![(1.0, 2.0)] }];
        let out = render(&s, 30, 8, true);
        assert!(out.contains('*'));
    }

    #[test]
    fn log_x_compresses_decades() {
        let s = vec![Series {
            label: "bits",
            points: vec![(1e3, 0.5), (1e6, 0.8), (1e9, 0.95)],
        }];
        let out = render(&s, 60, 10, true);
        // three distinct plotted columns despite the 1e6x range
        // (+1 star for the legend glyph line)
        let stars: usize = out.matches('*').count();
        assert_eq!(stars, 3 + 1, "{out}");
    }
}
