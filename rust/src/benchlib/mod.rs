//! Micro-benchmark harness (criterion-style; criterion itself is not in
//! the offline vendor set — see DESIGN.md).
//!
//! `cargo bench` targets under `rust/benches/` use [`Bench`] with
//! `harness = false`. Auto-calibrates iteration counts to a target
//! duration, reports mean/p50/p95, and supports throughput annotations.

use std::time::{Duration, Instant};

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct Stats {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    /// optional elements-per-iteration for throughput reporting
    pub elems: Option<u64>,
}

impl Stats {
    pub fn throughput_gelem_s(&self) -> Option<f64> {
        self.elems.map(|e| e as f64 / self.mean_ns)
    }

    pub fn report(&self) -> String {
        let tp = match self.throughput_gelem_s() {
            Some(t) => format!("  {:>8.3} Gelem/s", t),
            None => String::new(),
        };
        format!(
            "{:<44} {:>12} iters  mean {:>12}  p50 {:>12}  p95 {:>12}{}",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p95_ns),
            tp
        )
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.3} s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.3} ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.3} µs", ns / 1e3)
    } else {
        format!("{:.0} ns", ns)
    }
}

/// Benchmark runner: collects cases, prints a report, optionally writes
/// CSV under `results/bench_<suite>.csv`.
pub struct Bench {
    suite: String,
    target: Duration,
    pub results: Vec<Stats>,
}

impl Bench {
    pub fn new(suite: &str) -> Self {
        // honour a quick mode for CI-style runs
        let target = match std::env::var("MLMC_BENCH_MS") {
            Ok(ms) => Duration::from_millis(ms.parse().unwrap_or(300)),
            Err(_) => Duration::from_millis(300),
        };
        println!("== bench suite: {suite} ==");
        Bench { suite: suite.into(), target, results: Vec::new() }
    }

    /// Run `f` repeatedly; `f` must return something observable to keep
    /// the optimizer honest (its result is black-boxed).
    pub fn case<R>(&mut self, name: &str, mut f: impl FnMut() -> R) -> &Stats {
        self.case_with_elems(name, None, &mut f)
    }

    /// Like [`Bench::case`] with an elements-per-iteration annotation.
    pub fn case_elems<R>(&mut self, name: &str, elems: u64, mut f: impl FnMut() -> R) -> &Stats {
        self.case_with_elems(name, Some(elems), &mut f)
    }

    fn case_with_elems<R>(
        &mut self,
        name: &str,
        elems: Option<u64>,
        f: &mut dyn FnMut() -> R,
    ) -> &Stats {
        // calibration: find iteration count that fills ~target/5
        let mut iters = 1u64;
        loop {
            let t = Instant::now();
            for _ in 0..iters {
                black_box(f());
            }
            let el = t.elapsed();
            if el >= self.target / 5 || iters >= 1 << 24 {
                break;
            }
            let grow = if el.as_nanos() == 0 {
                16
            } else {
                ((self.target.as_nanos() / 5 / el.as_nanos()) + 1).min(16) as u64
            };
            iters = (iters * grow.max(2)).min(1 << 24);
        }
        // measurement: batches of `iters` until target elapsed
        let mut samples: Vec<f64> = Vec::new();
        let begin = Instant::now();
        while begin.elapsed() < self.target || samples.len() < 5 {
            let t = Instant::now();
            for _ in 0..iters {
                black_box(f());
            }
            samples.push(t.elapsed().as_nanos() as f64 / iters as f64);
            if samples.len() >= 200 {
                break;
            }
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let p50 = samples[samples.len() / 2];
        let p95_idx = ((samples.len() as f64 * 0.95) as usize).min(samples.len() - 1);
        let p95 = samples[p95_idx];
        let stats = Stats {
            name: name.to_string(),
            iters: iters * samples.len() as u64,
            mean_ns: mean,
            p50_ns: p50,
            p95_ns: p95,
            elems,
        };
        println!("{}", stats.report());
        self.results.push(stats);
        self.results.last().unwrap()
    }

    /// Write `results/bench_<suite>.csv`.
    pub fn write_csv(&self) {
        use std::io::Write;
        let path = crate::util::results_dir().join(format!("bench_{}.csv", self.suite));
        if let Ok(mut f) = std::fs::File::create(&path) {
            let _ = writeln!(f, "name,iters,mean_ns,p50_ns,p95_ns,elems");
            for s in &self.results {
                let _ = writeln!(
                    f,
                    "{},{},{:.1},{:.1},{:.1},{}",
                    s.name,
                    s.iters,
                    s.mean_ns,
                    s.p50_ns,
                    s.p95_ns,
                    s.elems.map(|e| e.to_string()).unwrap_or_default()
                );
            }
            println!("wrote {}", path.display());
        }
    }
}

/// Optimizer barrier (std::hint::black_box re-export point).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_ns_scales() {
        assert_eq!(fmt_ns(12.0), "12 ns");
        assert_eq!(fmt_ns(1500.0), "1.500 µs");
        assert_eq!(fmt_ns(2.5e6), "2.500 ms");
        assert_eq!(fmt_ns(3e9), "3.000 s");
    }

    #[test]
    fn bench_measures_something() {
        std::env::set_var("MLMC_BENCH_MS", "20");
        let mut b = Bench::new("selftest");
        let mut acc = 0u64;
        let s = b.case("noop-ish", || {
            acc = acc.wrapping_add(1);
            acc
        });
        assert!(s.mean_ns > 0.0);
        assert!(s.p95_ns >= s.p50_ns * 0.5);
        std::env::remove_var("MLMC_BENCH_MS");
    }

    #[test]
    fn throughput_annotation() {
        let s = Stats {
            name: "x".into(),
            iters: 1,
            mean_ns: 100.0,
            p50_ns: 100.0,
            p95_ns: 100.0,
            elems: Some(1000),
        };
        assert!((s.throughput_gelem_s().unwrap() - 10.0).abs() < 1e-12);
        assert!(s.report().contains("Gelem/s"));
    }
}
