//! Network cost model: converts the bit-exact communication counts into
//! wall-clock communication time for a parameterized link, so the
//! bits-x-axis figures can also be read as time-x-axis (the paper's
//! motivation: communication is the bottleneck, §1).
//!
//! [`cost`] builds on this: a deterministic, **lazy** per-worker cost
//! model (heterogeneous links + per-worker gradient-compute time +
//! seeded straggler delays) that the round engine uses to decide
//! simulated message arrival order — covering the full step, not just
//! the transfer. O(1) state: every per-worker quantity is recomputed on
//! demand from its `(seed, worker, step)` stream, so population size
//! costs nothing to hold. [`event`] turns priced arrivals into a lazy
//! min-heap popped in time order, and [`population`] wraps heap + cost
//! model into the O(active)-memory round simulator that scales virtual
//! mode to millions of workers.

pub mod cost;
pub mod event;
pub mod population;

pub use cost::{CostBreakdown, CostModel, CostSpec};
pub use event::{Event, EventHeap, HeapArrivals};
pub use population::{Population, RoundSim, SimRoundReport, Topology};

/// A simple star-topology link model (every worker has an identical
/// uplink to the server).
#[derive(Clone, Debug)]
pub struct LinkModel {
    /// uplink bandwidth, bits/second
    pub uplink_bps: f64,
    /// downlink (broadcast) bandwidth, bits/second
    pub downlink_bps: f64,
    /// per-message latency, seconds
    pub latency_s: f64,
}

impl LinkModel {
    /// Datacenter-ish 10 Gb/s symmetric link.
    pub fn datacenter() -> Self {
        LinkModel { uplink_bps: 10e9, downlink_bps: 10e9, latency_s: 50e-6 }
    }

    /// Federated/edge-ish 20 Mb/s up, 100 Mb/s down, 20 ms RTT.
    pub fn edge() -> Self {
        LinkModel { uplink_bps: 20e6, downlink_bps: 100e6, latency_s: 20e-3 }
    }

    /// Time for one worker to ship `bits` uplink.
    pub fn uplink_time(&self, bits: u64) -> f64 {
        self.latency_s + bits as f64 / self.uplink_bps
    }

    /// Time for the server to broadcast `bits` to M workers
    /// (sequential unicast model — the paper's master-server setting).
    pub fn broadcast_time(&self, bits: u64, workers: usize) -> f64 {
        self.latency_s + workers as f64 * bits as f64 / self.downlink_bps
    }

    /// One synchronous round: all M uplinks share the server's ingress
    /// (serialized), then a broadcast of the (uncompressed) model.
    pub fn round_time(&self, uplink_bits_per_worker: u64, model_bits: u64, workers: usize) -> f64 {
        let up: f64 = workers as f64 * (uplink_bits_per_worker as f64 / self.uplink_bps)
            + self.latency_s;
        up + self.broadcast_time(model_bits, workers)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uplink_scales_with_bits() {
        let l = LinkModel { uplink_bps: 1e6, downlink_bps: 1e6, latency_s: 0.01 };
        assert!((l.uplink_time(1_000_000) - 1.01).abs() < 1e-9);
        assert!((l.uplink_time(0) - 0.01).abs() < 1e-12);
    }

    #[test]
    fn broadcast_scales_with_workers() {
        let l = LinkModel { uplink_bps: 1e6, downlink_bps: 2e6, latency_s: 0.0 };
        let t4 = l.broadcast_time(1_000_000, 4);
        let t8 = l.broadcast_time(1_000_000, 8);
        assert!((t8 / t4 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn compression_reduces_round_time() {
        let l = LinkModel::edge();
        let model_bits = 32 * 100_000;
        let full = l.round_time(32 * 100_000, model_bits, 8);
        let comp = l.round_time(2 * 100_000, model_bits, 8); // fixed-point MLMC
        assert!(comp < full);
        // uplink-bound regime: the gap should be substantial
        assert!(full / comp > 2.0, "{} / {}", full, comp);
    }

    #[test]
    fn presets_sane() {
        assert!(LinkModel::datacenter().uplink_bps > LinkModel::edge().uplink_bps);
    }
}
