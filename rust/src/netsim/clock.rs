//! Back-compat shim: the deterministic virtual clock grew a per-worker
//! compute term and was promoted to the full [`super::cost::CostModel`].
//! Existing imports of `netsim::clock::{VirtualClock, preset_names}`
//! keep working through this module; new code should use
//! [`crate::netsim::CostModel`] directly.

pub use super::cost::preset_names;

/// The pre-cost-model name for [`super::cost::CostModel`]. With a zero
/// compute term (the three original presets) arrival times are
/// bit-identical to the PR 2 clock, so every pre-existing trajectory
/// replays unchanged under the alias.
pub type VirtualClock = super::cost::CostModel;
