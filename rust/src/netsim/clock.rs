//! Deterministic virtual clock over [`LinkModel`]s: per-worker
//! heterogeneous links plus a seeded straggler-delay distribution decide
//! the *simulated* arrival order of worker messages, so every run
//! reports simulated wall-clock time alongside the bit-exact uplink
//! accounting — the figures' bits x-axis gains a time x-axis for free,
//! and straggler-tolerant participation policies (quorum, sampling)
//! become expressible without real asynchrony.
//!
//! Determinism contract: [`VirtualClock::arrival_s`] is a pure function
//! of `(step, worker, up_bits, down_bits)` — it never depends on the
//! order messages were physically gathered (permutation stability) or on
//! wall time, and the straggler draw comes from the dedicated
//! `(seed, worker, step)` RNG stream, so repeated runs replay exactly.

use super::LinkModel;
use crate::tensor::Rng;

/// Stream salt for per-worker link heterogeneity factors.
const LINK_SALT: u64 = 0x11_4B5;
/// Stream salt for per-(worker, step) straggler delays.
const STRAGGLER_SALT: u64 = 0x57_4A66;

/// Known link presets for the `link` config knob.
pub fn preset_names() -> &'static [&'static str] {
    &["datacenter", "edge", "hetero"]
}

/// Simulated time source for the round engine.
#[derive(Clone, Debug)]
pub struct VirtualClock {
    links: Vec<LinkModel>,
    straggler_mean_s: f64,
    seed: u64,
    now_s: f64,
}

impl VirtualClock {
    /// Per-worker links derived from `base`: worker `w`'s bandwidths are
    /// scaled by a deterministic factor in `[1/spread, 1]` (and its
    /// latency inflated by the inverse), drawn once per worker from the
    /// `(seed, worker)` stream. `spread <= 1` means homogeneous links.
    pub fn new(
        base: &LinkModel,
        workers: usize,
        spread: f64,
        straggler_mean_s: f64,
        seed: u64,
    ) -> Self {
        let spread = spread.max(1.0);
        let links = (0..workers)
            .map(|w| {
                let f = if spread > 1.0 {
                    let u = Rng::for_stream(seed ^ LINK_SALT, w as u64, 0).uniform();
                    1.0 / (1.0 + (spread - 1.0) * u)
                } else {
                    1.0
                };
                LinkModel {
                    uplink_bps: base.uplink_bps * f,
                    downlink_bps: base.downlink_bps * f,
                    latency_s: base.latency_s / f,
                }
            })
            .collect();
        VirtualClock { links, straggler_mean_s: straggler_mean_s.max(0.0), seed, now_s: 0.0 }
    }

    /// Build from a named preset: `"datacenter"` / `"edge"` (homogeneous)
    /// or `"hetero"` (edge base with a 4x per-worker bandwidth spread).
    pub fn from_preset(
        name: &str,
        workers: usize,
        straggler_mean_s: f64,
        seed: u64,
    ) -> Option<Self> {
        let (base, spread) = match name {
            "datacenter" => (LinkModel::datacenter(), 1.0),
            "edge" => (LinkModel::edge(), 1.0),
            "hetero" => (LinkModel::edge(), 4.0),
            _ => return None,
        };
        Some(Self::new(&base, workers, spread, straggler_mean_s, seed))
    }

    pub fn workers(&self) -> usize {
        self.links.len()
    }

    pub fn link(&self, worker: u32) -> &LinkModel {
        &self.links[worker as usize]
    }

    /// Exponential straggler delay for `(worker, step)` via inverse-CDF
    /// sampling on the dedicated stream; 0 when stragglers are disabled.
    pub fn straggler_s(&self, step: u64, worker: u32) -> f64 {
        if self.straggler_mean_s <= 0.0 {
            return 0.0;
        }
        let u = Rng::for_stream(self.seed ^ STRAGGLER_SALT, worker as u64, step).uniform();
        -self.straggler_mean_s * (1.0 - u).ln()
    }

    /// Simulated arrival time — relative to the round start — of worker
    /// `w`'s uplink message of `up_bits`, after it downloaded the
    /// `down_bits` params broadcast over its own link. Pure in
    /// `(step, worker, up_bits, down_bits)`.
    pub fn arrival_s(&self, step: u64, worker: u32, up_bits: u64, down_bits: u64) -> f64 {
        let l = &self.links[worker as usize];
        let down = l.latency_s + down_bits as f64 / l.downlink_bps;
        let up = l.latency_s + up_bits as f64 / l.uplink_bps;
        down + up + self.straggler_s(step, worker)
    }

    /// Advance simulated time by one round's duration.
    pub fn advance(&mut self, round_s: f64) -> f64 {
        self.now_s += round_s.max(0.0);
        self.now_s
    }

    /// Simulated wall-clock since the run started.
    pub fn now_s(&self) -> f64 {
        self.now_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_build_and_unknown_rejected() {
        for name in preset_names() {
            let c = VirtualClock::from_preset(name, 4, 0.0, 1).unwrap();
            assert_eq!(c.workers(), 4);
        }
        assert!(VirtualClock::from_preset("carrier-pigeon", 4, 0.0, 1).is_none());
    }

    #[test]
    fn arrival_is_pure_and_deterministic() {
        let a = VirtualClock::from_preset("hetero", 8, 0.02, 7).unwrap();
        let b = VirtualClock::from_preset("hetero", 8, 0.02, 7).unwrap();
        for step in 0..5 {
            for w in 0..8u32 {
                let t1 = a.arrival_s(step, w, 10_000, 320_000);
                let t2 = a.arrival_s(step, w, 10_000, 320_000);
                let t3 = b.arrival_s(step, w, 10_000, 320_000);
                assert_eq!(t1.to_bits(), t2.to_bits());
                assert_eq!(t1.to_bits(), t3.to_bits());
                assert!(t1 > 0.0);
            }
        }
        // different seed shifts the straggler draws
        let c = VirtualClock::from_preset("hetero", 8, 0.02, 8).unwrap();
        assert_ne!(
            a.arrival_s(0, 0, 10_000, 320_000).to_bits(),
            c.arrival_s(0, 0, 10_000, 320_000).to_bits()
        );
    }

    #[test]
    fn hetero_spread_slows_some_workers() {
        let hom = VirtualClock::from_preset("edge", 8, 0.0, 3).unwrap();
        let het = VirtualClock::from_preset("hetero", 8, 0.0, 3).unwrap();
        let t_hom: Vec<f64> = (0..8).map(|w| hom.arrival_s(0, w, 1_000_000, 0)).collect();
        let t_het: Vec<f64> = (0..8).map(|w| het.arrival_s(0, w, 1_000_000, 0)).collect();
        // homogeneous: identical; heterogeneous: a real spread, never faster
        assert!(t_hom.windows(2).all(|p| p[0] == p[1]));
        let (min, max) = t_het
            .iter()
            .fold((f64::INFINITY, 0.0f64), |(lo, hi), &t| (lo.min(t), hi.max(t)));
        assert!(max > 1.5 * min, "spread too small: {min}..{max}");
        assert!(min >= t_hom[0], "hetero workers cannot beat the base link");
    }

    #[test]
    fn straggler_delays_nonnegative_with_sane_mean() {
        let c = VirtualClock::from_preset("datacenter", 4, 0.05, 11).unwrap();
        let mut sum = 0.0;
        let n = 2000;
        for step in 0..n {
            for w in 0..4u32 {
                let s = c.straggler_s(step, w);
                assert!(s >= 0.0);
                sum += s;
            }
        }
        let mean = sum / (4 * n) as f64;
        assert!((mean - 0.05).abs() < 0.01, "empirical mean {mean}");
        // disabled stragglers are exactly zero
        let c0 = VirtualClock::from_preset("datacenter", 4, 0.0, 11).unwrap();
        assert_eq!(c0.straggler_s(0, 0), 0.0);
    }

    #[test]
    fn clock_monotone_under_advance() {
        let mut c = VirtualClock::from_preset("edge", 2, 0.0, 1).unwrap();
        let mut prev = c.now_s();
        for step in 0..10 {
            let dur = c.arrival_s(step, 0, 1000, 1000);
            let now = c.advance(dur);
            assert!(now >= prev);
            assert!(now > prev, "positive-latency rounds must advance time");
            prev = now;
        }
        // negative durations are clamped, never rewinding time
        let before = c.now_s();
        assert_eq!(c.advance(-5.0), before);
    }
}
