//! Lazy **event heap** for virtual-mode rounds: per-round arrivals as a
//! min-heap of `(arrival_s, worker)` events popped in time order.
//!
//! The heap holds one [`Event`] per *active* participant — never one per
//! population member — so a sampled round over a million-worker
//! population costs O(active) memory. Events are priced on demand by the
//! pure [`super::CostModel::price`] stream contract, and because the
//! event ordering is total (ties broken by worker id, times never NaN),
//! popping the heap to exhaustion yields exactly the sequence an eager
//! sort of the same arrivals would — the bit-identity bridge between the
//! heap path and the historical eager path.
//!
//! [`HeapArrivals`] adapts a heap to the
//! [`crate::engine::policy::ArrivalView`] close protocol: policies read
//! the sorted prefix they need (`nth(i)` pops lazily, with free replay
//! of what was already popped), and [`HeapArrivals::into_parts`] hands
//! the popped prefix + untouched remainder back to the simulator for the
//! on-time/late partition.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::engine::policy::{Arrival, ArrivalView};

/// One pending uplink arrival: worker `worker`'s reply lands at `at_s`
/// seconds after the round start. Ordered by `(at_s, worker)` — a total
/// order because simulated arrival times are never NaN (they are sums of
/// finite link/compute/straggler terms).
#[derive(Clone, Copy, Debug)]
pub struct Event {
    pub at_s: f64,
    pub worker: u32,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for Event {}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        self.at_s
            .partial_cmp(&other.at_s)
            .expect("arrival times are never NaN")
            .then(self.worker.cmp(&other.worker))
    }
}

/// Min-heap of pending arrivals, popped in `(at_s, worker)` order.
/// O(active) memory: holds only the events pushed into it.
#[derive(Clone, Debug)]
pub struct EventHeap {
    heap: BinaryHeap<std::cmp::Reverse<Event>>,
}

impl EventHeap {
    pub fn new() -> Self {
        EventHeap { heap: BinaryHeap::new() }
    }

    pub fn with_capacity(n: usize) -> Self {
        EventHeap { heap: BinaryHeap::with_capacity(n) }
    }

    pub fn push(&mut self, event: Event) {
        self.heap.push(std::cmp::Reverse(event));
    }

    /// Remove and return the earliest pending event.
    pub fn pop(&mut self) -> Option<Event> {
        self.heap.pop().map(|std::cmp::Reverse(e)| e)
    }

    /// The earliest pending event, without removing it.
    pub fn peek(&self) -> Option<Event> {
        self.heap.peek().map(|&std::cmp::Reverse(e)| e)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Drain every remaining worker id **without** sorting — O(n), for
    /// consumers (late-set collection) that order the result themselves.
    pub fn drain_workers(self) -> impl Iterator<Item = u32> {
        self.heap.into_iter().map(|std::cmp::Reverse(e)| e.worker)
    }
}

/// An [`ArrivalView`] over an [`EventHeap`]: `nth(i)` lazily pops the
/// heap down to the i-th smallest arrival, keeping the popped prefix for
/// free replay (policies and the engine may both index into it, in any
/// order, without re-pricing). `population` reports the full simulated
/// population M — not the heap size — so sampling-aware policies see the
/// world they are drawing from.
#[derive(Debug)]
pub struct HeapArrivals {
    heap: EventHeap,
    prefix: Vec<Arrival>,
    population: usize,
}

impl HeapArrivals {
    pub fn new(heap: EventHeap, population: usize) -> Self {
        HeapArrivals { heap, prefix: Vec::new(), population }
    }

    /// Number of active participants this round (popped + pending).
    pub fn active(&self) -> usize {
        self.prefix.len() + self.heap.len()
    }

    /// Decompose into the sorted popped prefix and the untouched
    /// remainder of the heap, for the round's on-time/late partition.
    pub fn into_parts(self) -> (Vec<Arrival>, EventHeap) {
        (self.prefix, self.heap)
    }
}

impl ArrivalView for HeapArrivals {
    fn population(&self) -> usize {
        self.population
    }

    fn nth(&mut self, i: usize) -> Option<Arrival> {
        while self.prefix.len() <= i {
            match self.heap.pop() {
                Some(e) => self.prefix.push(Arrival { worker: e.worker, at_s: e.at_s }),
                None => return None,
            }
        }
        Some(self.prefix[i])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn heap_of(events: &[(f64, u32)]) -> EventHeap {
        let mut h = EventHeap::with_capacity(events.len());
        for &(at_s, worker) in events {
            h.push(Event { at_s, worker });
        }
        h
    }

    #[test]
    fn pop_order_equals_eager_sort() {
        let events = [(0.5, 3u32), (0.1, 7), (0.9, 0), (0.1, 2), (0.3, 5), (0.5, 1)];
        let mut h = heap_of(&events);
        let mut eager: Vec<Event> =
            events.iter().map(|&(at_s, worker)| Event { at_s, worker }).collect();
        eager.sort();
        let mut popped = Vec::new();
        while let Some(e) = h.pop() {
            popped.push(e);
        }
        assert_eq!(popped, eager);
        // ties broke by worker id: (0.1, 2) before (0.1, 7)
        assert_eq!(popped[0].worker, 2);
        assert_eq!(popped[1].worker, 7);
    }

    #[test]
    fn peek_matches_pop_and_len_tracks() {
        let mut h = heap_of(&[(2.0, 1), (1.0, 9)]);
        assert_eq!(h.len(), 2);
        assert!(!h.is_empty());
        assert_eq!(h.peek().unwrap().worker, 9);
        assert_eq!(h.pop().unwrap().worker, 9);
        assert_eq!(h.pop().unwrap().worker, 1);
        assert!(h.pop().is_none());
        assert!(h.is_empty());
    }

    #[test]
    fn drain_workers_returns_every_pending_id() {
        let h = heap_of(&[(0.4, 4), (0.2, 2), (0.6, 6)]);
        let mut ids: Vec<u32> = h.drain_workers().collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![2, 4, 6]);
    }

    #[test]
    fn view_nth_replays_and_bounds() {
        let h = heap_of(&[(0.3, 1), (0.1, 2), (0.2, 0)]);
        let mut v = HeapArrivals::new(h, 100);
        assert_eq!(v.population(), 100);
        assert_eq!(v.active(), 3);
        // random access, out of order, with replay
        assert_eq!(v.nth(2).unwrap().worker, 1);
        assert_eq!(v.nth(0).unwrap().worker, 2);
        assert_eq!(v.nth(1).unwrap().worker, 0);
        assert_eq!(v.nth(0).unwrap().at_s, 0.1);
        assert!(v.nth(3).is_none());
        // exhausting nth leaves an empty heap, full prefix
        let (prefix, rest) = v.into_parts();
        assert_eq!(prefix.len(), 3);
        assert!(rest.is_empty());
    }

    #[test]
    fn into_parts_splits_popped_from_pending() {
        let h = heap_of(&[(0.3, 1), (0.1, 2), (0.2, 0), (0.4, 5)]);
        let mut v = HeapArrivals::new(h, 4);
        v.nth(1); // pops two
        let (prefix, rest) = v.into_parts();
        assert_eq!(prefix.iter().map(|a| a.worker).collect::<Vec<_>>(), vec![2, 0]);
        assert_eq!(rest.len(), 2);
        assert!(prefix.last().unwrap().at_s <= rest.peek().unwrap().at_s);
    }
}
