//! Deterministic cluster **cost model**: prices a worker's simulated
//! per-step arrival time as
//!
//! ```text
//! arrival = download + compute + upload + straggler
//! ```
//!
//! where `download`/`upload` come from per-worker heterogeneous
//! [`LinkModel`] factors, **compute** is a per-worker
//! gradient-computation term (base seconds × a seeded per-worker
//! slowdown factor), and the straggler term is a seeded exponential
//! delay.
//!
//! # Lazy by construction
//!
//! [`CostModel`] stores **no per-worker state** — construction is O(1)
//! in the population size. Every per-worker quantity (link factor,
//! compute slowdown, straggler delay) is recomputed on demand from its
//! dedicated `(seed, worker)` / `(seed, worker, step)` RNG stream, so a
//! million-worker population costs exactly as much to build as a
//! four-worker one, and only the workers a round actually prices are
//! ever touched. This is what lets the event-heap simulator
//! ([`super::population`]) run at M = 10⁶ in O(active) memory.
//!
//! Construction goes through the [`CostSpec`] builder (order-insensitive
//! named setters — there is no positional constructor), and all pricing
//! through the one pure entry point [`CostModel::price`], which returns
//! a [`CostBreakdown`] of the four terms.
//!
//! Determinism contract: [`CostModel::price`] (and its sum,
//! [`CostModel::arrival_s`]) is a pure function of `(step, worker,
//! up_bits, down_bits)` — it never depends on the order messages were
//! physically gathered (permutation stability) or on wall time, so
//! repeated runs replay exactly.
//!
//! Bit-compatibility contract: the lazily recomputed arrival times are
//! **bit-identical** to the historical eager model (per-worker
//! `LinkModel`/compute vectors materialized up front): the factor
//! streams, salts, and floating-point operation order are unchanged,
//! and with a zero compute term arrivals are bit-identical all the way
//! back to the pre-cost-model `VirtualClock`.

use super::LinkModel;
use crate::config::TrainConfig;
use crate::tensor::Rng;
use anyhow::{bail, Result};

/// Stream salt for per-worker link heterogeneity factors.
const LINK_SALT: u64 = 0x11_4B5;
/// Stream salt for per-(worker, step) straggler delays.
const STRAGGLER_SALT: u64 = 0x57_4A66;
/// Stream salt for per-worker compute slowdown factors.
const COMPUTE_SALT: u64 = 0xC0_4B7E;

/// Known presets for the `link` config knob.
pub fn preset_names() -> &'static [&'static str] {
    &["datacenter", "edge", "hetero", "hetero-compute"]
}

/// Fixed cost of one gradient step (fwd/bwd bookkeeping, RNG stream
/// setup, compressor prologue), seconds. Fitted with
/// [`COMPUTE_FIT_PER_ELEM_S`] by least squares against the per-round
/// `compute+compress` timings that `benches/rounds.rs` emits into
/// `results/BENCH_rounds.json` (`fitted_compute` block) on the CI
/// runner class; re-run that bench to refit after hardware changes.
pub const COMPUTE_FIT_BASE_S: f64 = 2.1e-4;
/// Per-element slope of the same linear fit: marginal seconds per
/// gradient coordinate (quadratic terms were indistinguishable from
/// noise across d = 10³..10⁶). See [`COMPUTE_FIT_BASE_S`].
pub const COMPUTE_FIT_PER_ELEM_S: f64 = 1.6e-9;

/// Calibrated per-step gradient-compute seconds for a model of
/// dimension `d`: the measured linear fit
/// `COMPUTE_FIT_BASE_S + d * COMPUTE_FIT_PER_ELEM_S`. This is the value
/// the `compute = "auto"` config knob installs as the cost model's base
/// compute term (per-worker spread still comes from `compute_spread`).
pub fn calibrated_compute_s(d: usize) -> f64 {
    COMPUTE_FIT_BASE_S + d as f64 * COMPUTE_FIT_PER_ELEM_S
}

/// Order-insensitive builder for [`CostModel`]: start from a base link
/// ([`CostSpec::link`]) or a named preset ([`CostSpec::preset`]), then
/// name whichever knobs differ from the defaults, in any order.
///
/// ```no_run
/// use mlmc_dist::netsim::CostSpec;
/// let cost = CostSpec::preset("hetero")?
///     .workers(1_000_000)
///     .straggler(0.05)
///     .seed(7)
///     .build();
/// # anyhow::Result::<()>::Ok(())
/// ```
#[derive(Clone, Debug)]
pub struct CostSpec {
    base: LinkModel,
    link_spread: f64,
    compute_base_s: f64,
    compute_spread: f64,
    straggler_mean_s: f64,
    seed: u64,
    workers: usize,
}

impl CostSpec {
    /// Start from an explicit base link: homogeneous (spread 1), no
    /// compute term, no stragglers, seed 0, one worker.
    pub fn link(base: LinkModel) -> Self {
        CostSpec {
            base,
            link_spread: 1.0,
            compute_base_s: 0.0,
            compute_spread: 1.0,
            straggler_mean_s: 0.0,
            seed: 0,
            workers: 1,
        }
    }

    /// Start from a named preset ([`preset_names`]):
    ///
    /// * `"datacenter"` / `"edge"` — homogeneous links, no compute term
    /// * `"hetero"` — edge base with a 4x per-worker bandwidth spread
    /// * `"hetero-compute"` — `hetero` plus a default compute term
    ///   (20 ms base, 4x per-worker spread), so the arrival elbow is
    ///   shaped by compute *and* transfer. An explicit
    ///   [`CostSpec::compute`] call replaces this whole term, spread
    ///   included.
    ///
    /// Unknown names are a loud, centralized error listing the known
    /// presets — call sites must not re-implement the message.
    pub fn preset(name: &str) -> Result<Self> {
        Ok(match name {
            "datacenter" => Self::link(LinkModel::datacenter()),
            "edge" => Self::link(LinkModel::edge()),
            "hetero" => Self::link(LinkModel::edge()).link_spread(4.0),
            "hetero-compute" => {
                Self::link(LinkModel::edge()).link_spread(4.0).compute(0.02, 4.0)
            }
            _ => bail!("unknown link preset {name:?} (known: {:?})", preset_names()),
        })
    }

    /// A config's cost-model knobs (`link` / `straggler` / `seed` /
    /// `compute` / `compute_spread`), sized to `workers`: the preset's
    /// built-in compute term applies unless the config carries an
    /// explicit `compute > 0`, which replaces it — spread included.
    ///
    /// `compute = "auto"` resolves through the dimension-aware form
    /// [`CostSpec::from_train_cfg_for_dim`]; this dimension-less
    /// shorthand uses `d = 0`, i.e. the fitted fixed cost
    /// [`COMPUTE_FIT_BASE_S`] alone.
    pub fn from_train_cfg(cfg: &TrainConfig, workers: usize) -> Result<Self> {
        Self::from_train_cfg_for_dim(cfg, workers, 0)
    }

    /// [`CostSpec::from_train_cfg`] with the model dimension known:
    /// when the config says `compute = "auto"` (`compute_auto`), the
    /// compute term is the measured fit [`calibrated_compute_s`]`(d)`
    /// with the config's `compute_spread`; an explicit `compute > 0`
    /// still wins as before, and with neither the preset's built-in
    /// term applies unchanged.
    pub fn from_train_cfg_for_dim(cfg: &TrainConfig, workers: usize, d: usize) -> Result<Self> {
        let mut spec =
            Self::preset(&cfg.link)?.workers(workers).straggler(cfg.straggler).seed(cfg.seed);
        if cfg.compute_auto {
            spec = spec.compute(calibrated_compute_s(d), cfg.compute_spread);
        } else if cfg.compute > 0.0 {
            spec = spec.compute(cfg.compute, cfg.compute_spread);
        }
        Ok(spec)
    }

    /// Population size M (worker ids are `0..workers`).
    pub fn workers(mut self, workers: usize) -> Self {
        self.workers = workers;
        self
    }

    /// Per-worker link spread: worker `w`'s bandwidths are scaled by a
    /// deterministic factor in `[1/spread, 1]` (and its latency inflated
    /// by the inverse), drawn from the `(seed, worker)` link stream.
    /// `spread <= 1` means homogeneous links.
    pub fn link_spread(mut self, spread: f64) -> Self {
        self.link_spread = spread;
        self
    }

    /// Per-worker gradient-compute term: worker `w` takes `base_s * f_w`
    /// seconds per step, with `f_w` a deterministic slowdown factor in
    /// `[1, spread]` from the `(seed, worker)` compute stream
    /// (`spread <= 1` = homogeneous compute; `base_s <= 0` clears the
    /// term).
    pub fn compute(mut self, base_s: f64, spread: f64) -> Self {
        self.compute_base_s = base_s;
        self.compute_spread = spread;
        self
    }

    /// Mean of the seeded exponential straggler delay (`<= 0` = off).
    pub fn straggler(mut self, mean_s: f64) -> Self {
        self.straggler_mean_s = mean_s;
        self
    }

    /// Seed for every per-worker/per-step stream.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Finalize: clamp the knobs into their legal ranges and wrap the
    /// spec in a [`CostModel`] with the simulated clock at zero. O(1) —
    /// no per-worker state is materialized, at any population size.
    pub fn build(mut self) -> CostModel {
        self.link_spread = self.link_spread.max(1.0);
        self.compute_base_s = self.compute_base_s.max(0.0);
        self.compute_spread = self.compute_spread.max(1.0);
        self.straggler_mean_s = self.straggler_mean_s.max(0.0);
        CostModel { spec: self, now_s: 0.0 }
    }
}

/// The four priced components of one simulated arrival, as returned by
/// [`CostModel::price`]. The arrival time is their sum
/// ([`CostBreakdown::total`]), in the fixed order download → compute →
/// upload → straggler (the historical summation order, kept for bit
/// compatibility).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CostBreakdown {
    /// params-broadcast download over the worker's own link
    pub down_s: f64,
    /// per-worker gradient-compute seconds
    pub compute_s: f64,
    /// reply upload over the worker's own link
    pub up_s: f64,
    /// seeded exponential straggler delay
    pub straggler_s: f64,
}

impl CostBreakdown {
    /// The arrival time this breakdown prices (fixed summation order).
    pub fn total(&self) -> f64 {
        self.down_s + self.compute_s + self.up_s + self.straggler_s
    }
}

/// Simulated per-step cost source for the round engine and the
/// event-heap simulator: heterogeneous links + per-worker compute +
/// seeded stragglers, plus the run's simulated wall-clock accumulator.
/// O(1) state — see the module docs for the lazy-pricing contract.
/// Built via [`CostSpec`].
#[derive(Clone, Debug)]
pub struct CostModel {
    spec: CostSpec,
    now_s: f64,
}

impl CostModel {
    /// Shorthand for the common preset construction
    /// (`CostSpec::preset(name)?.workers(m).straggler(s).seed(seed)`).
    pub fn from_preset(
        name: &str,
        workers: usize,
        straggler_mean_s: f64,
        seed: u64,
    ) -> Result<Self> {
        Ok(CostSpec::preset(name)?.workers(workers).straggler(straggler_mean_s).seed(seed).build())
    }

    /// Replace the compute term ([`CostSpec::compute`]) on a built
    /// model. Order-insensitive: pricing is lazy, so this composes with
    /// any other knob in any order.
    pub fn with_compute(mut self, base_s: f64, spread: f64) -> Self {
        self.spec.compute_base_s = base_s.max(0.0);
        self.spec.compute_spread = spread.max(1.0);
        self
    }

    /// Population size M.
    pub fn workers(&self) -> usize {
        self.spec.workers
    }

    /// Worker `w`'s link slowdown factor in `[1/spread, 1]`, recomputed
    /// from the `(seed, worker)` link stream (1 when homogeneous).
    fn link_factor(&self, worker: u32) -> f64 {
        if self.spec.link_spread > 1.0 {
            let u = Rng::for_stream(self.spec.seed ^ LINK_SALT, worker as u64, 0).uniform();
            1.0 / (1.0 + (self.spec.link_spread - 1.0) * u)
        } else {
            1.0
        }
    }

    /// Worker `w`'s per-step compute seconds, recomputed from the
    /// `(seed, worker)` compute stream.
    fn compute_of(&self, worker: u32) -> f64 {
        let f = if self.spec.compute_spread > 1.0 {
            let u = Rng::for_stream(self.spec.seed ^ COMPUTE_SALT, worker as u64, 0).uniform();
            1.0 + (self.spec.compute_spread - 1.0) * u
        } else {
            1.0
        };
        self.spec.compute_base_s * f
    }

    /// Exponential straggler delay for `(worker, step)` via inverse-CDF
    /// sampling on the dedicated stream; 0 when stragglers are disabled.
    pub fn straggler_s(&self, step: u64, worker: u32) -> f64 {
        if self.spec.straggler_mean_s <= 0.0 {
            return 0.0;
        }
        let u = Rng::for_stream(self.spec.seed ^ STRAGGLER_SALT, worker as u64, step).uniform();
        // detmath::ln is the float_det-approved deterministic log: libm's
        // ln is platform-dependent, which would break cross-machine replay
        // of the priced cost stream.
        -self.spec.straggler_mean_s * crate::util::detmath::ln(1.0 - u)
    }

    /// THE pricing entry point: the four cost components of worker `w`'s
    /// simulated step — download the `down_bits` params broadcast over
    /// its own link, compute the gradient, upload the `up_bits` reply,
    /// plus the straggler draw. Pure in `(step, worker, up_bits,
    /// down_bits)`; every per-worker factor is recomputed from its
    /// stream, never stored.
    pub fn price(&self, step: u64, worker: u32, up_bits: u64, down_bits: u64) -> CostBreakdown {
        debug_assert!(
            (worker as usize) < self.spec.workers,
            "worker {worker} outside population 0..{}",
            self.spec.workers
        );
        let f = self.link_factor(worker);
        let latency_s = self.spec.base.latency_s / f;
        CostBreakdown {
            down_s: latency_s + down_bits as f64 / (self.spec.base.downlink_bps * f),
            compute_s: self.compute_of(worker),
            up_s: latency_s + up_bits as f64 / (self.spec.base.uplink_bps * f),
            straggler_s: self.straggler_s(step, worker),
        }
    }

    /// Simulated arrival time — relative to the round start — of worker
    /// `w`'s uplink message: [`CostModel::price`] summed.
    pub fn arrival_s(&self, step: u64, worker: u32, up_bits: u64, down_bits: u64) -> f64 {
        self.price(step, worker, up_bits, down_bits).total()
    }

    /// One relay hop through a sub-aggregator: the base link's latency
    /// plus serializing `bits` onto its uplink. Tree-topology rounds add
    /// this to every leaf arrival — the sub-aggregator relays replies
    /// cut-through over the base link (aggregator nodes sit on the good
    /// part of the network, so no heterogeneity factor applies).
    pub fn relay_hop_s(&self, bits: u64) -> f64 {
        self.spec.base.latency_s + bits as f64 / self.spec.base.uplink_bps
    }

    /// Advance simulated time by one round's duration.
    pub fn advance(&mut self, round_s: f64) -> f64 {
        self.now_s += round_s.max(0.0);
        self.now_s
    }

    /// Simulated wall-clock since the run started.
    pub fn now_s(&self) -> f64 {
        self.now_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_build_and_unknown_rejected_loudly() {
        for name in preset_names() {
            let c = CostModel::from_preset(name, 4, 0.0, 1).unwrap();
            assert_eq!(c.workers(), 4);
        }
        let err = CostModel::from_preset("carrier-pigeon", 4, 0.0, 1).unwrap_err().to_string();
        assert!(err.contains("carrier-pigeon"), "{err}");
        for name in preset_names() {
            assert!(err.contains(name), "error must list {name}: {err}");
        }
        // the builder surfaces the same centralized message
        let err = CostSpec::preset("smoke-signals").unwrap_err().to_string();
        assert!(err.contains("smoke-signals"), "{err}");
    }

    #[test]
    fn builder_is_order_insensitive_and_matches_from_preset() {
        let a = CostSpec::preset("hetero").unwrap().workers(8).straggler(0.02).seed(7).build();
        let b = CostSpec::preset("hetero").unwrap().seed(7).straggler(0.02).workers(8).build();
        let c = CostModel::from_preset("hetero", 8, 0.02, 7).unwrap();
        for step in 0..3 {
            for w in 0..8u32 {
                let t = a.arrival_s(step, w, 10_000, 320_000);
                assert_eq!(t.to_bits(), b.arrival_s(step, w, 10_000, 320_000).to_bits());
                assert_eq!(t.to_bits(), c.arrival_s(step, w, 10_000, 320_000).to_bits());
            }
        }
        // compute placement in the chain does not matter either
        let d = CostSpec::preset("edge").unwrap().compute(0.05, 4.0).workers(4).seed(3).build();
        let e = CostSpec::preset("edge").unwrap().seed(3).workers(4).compute(0.05, 4.0).build();
        for w in 0..4u32 {
            assert_eq!(
                d.arrival_s(0, w, 1_000, 1_000).to_bits(),
                e.arrival_s(0, w, 1_000, 1_000).to_bits()
            );
        }
    }

    #[test]
    fn price_components_sum_to_arrival_and_are_nonnegative() {
        let c = CostModel::from_preset("hetero-compute", 6, 0.04, 13).unwrap();
        for step in 0..4 {
            for w in 0..6u32 {
                let p = c.price(step, w, 10_000, 320_000);
                assert!(p.down_s > 0.0 && p.compute_s > 0.0 && p.up_s > 0.0);
                assert!(p.straggler_s >= 0.0);
                assert_eq!(p.total().to_bits(), c.arrival_s(step, w, 10_000, 320_000).to_bits());
            }
        }
    }

    #[test]
    fn arrival_is_pure_and_deterministic() {
        let a = CostModel::from_preset("hetero", 8, 0.02, 7).unwrap();
        let b = CostModel::from_preset("hetero", 8, 0.02, 7).unwrap();
        for step in 0..5 {
            for w in 0..8u32 {
                let t1 = a.arrival_s(step, w, 10_000, 320_000);
                let t2 = a.arrival_s(step, w, 10_000, 320_000);
                let t3 = b.arrival_s(step, w, 10_000, 320_000);
                assert_eq!(t1.to_bits(), t2.to_bits());
                assert_eq!(t1.to_bits(), t3.to_bits());
                assert!(t1 > 0.0);
            }
        }
        // different seed shifts the straggler draws
        let c = CostModel::from_preset("hetero", 8, 0.02, 8).unwrap();
        assert_ne!(
            a.arrival_s(0, 0, 10_000, 320_000).to_bits(),
            c.arrival_s(0, 0, 10_000, 320_000).to_bits()
        );
    }

    #[test]
    fn hetero_spread_slows_some_workers() {
        let hom = CostModel::from_preset("edge", 8, 0.0, 3).unwrap();
        let het = CostModel::from_preset("hetero", 8, 0.0, 3).unwrap();
        let t_hom: Vec<f64> = (0..8).map(|w| hom.arrival_s(0, w, 1_000_000, 0)).collect();
        let t_het: Vec<f64> = (0..8).map(|w| het.arrival_s(0, w, 1_000_000, 0)).collect();
        // homogeneous: identical; heterogeneous: a real spread, never faster
        assert!(t_hom.windows(2).all(|p| p[0] == p[1]));
        let (min, max) = t_het
            .iter()
            .fold((f64::INFINITY, 0.0f64), |(lo, hi), &t| (lo.min(t), hi.max(t)));
        assert!(max > 1.5 * min, "spread too small: {min}..{max}");
        assert!(min >= t_hom[0], "hetero workers cannot beat the base link");
    }

    #[test]
    fn compute_term_is_additive_monotone_and_per_worker() {
        let base = CostModel::from_preset("hetero", 8, 0.0, 5).unwrap();
        let slow = base.clone().with_compute(0.05, 1.0);
        let slower = base.clone().with_compute(0.10, 1.0);
        for w in 0..8u32 {
            let t0 = base.arrival_s(0, w, 10_000, 320_000);
            let t1 = slow.arrival_s(0, w, 10_000, 320_000);
            let t2 = slower.arrival_s(0, w, 10_000, 320_000);
            // homogeneous compute: exactly additive, monotone in base_s
            assert!((t1 - t0 - 0.05).abs() < 1e-12, "worker {w}: {t0} {t1}");
            assert!(t2 > t1 && t1 > t0);
            assert_eq!(slow.price(0, w, 10_000, 320_000).compute_s, 0.05);
        }
        // spread > 1: every worker in [base, base*spread], not all equal
        let spread = base.with_compute(0.05, 4.0);
        let cs: Vec<f64> = (0..8).map(|w| spread.price(0, w, 0, 0).compute_s).collect();
        assert!(cs.iter().all(|&c| (0.05..=0.2 + 1e-12).contains(&c)), "{cs:?}");
        assert!(cs.windows(2).any(|p| p[0] != p[1]), "compute spread drew no spread: {cs:?}");
        // the draw is per worker, fixed across steps (pure)
        for w in 0..8u32 {
            assert_eq!(
                spread.arrival_s(3, w, 10_000, 0).to_bits(),
                spread.arrival_s(3, w, 10_000, 0).to_bits()
            );
        }
    }

    #[test]
    fn zero_compute_matches_link_only_formula_bitwise() {
        // the pre-cost-model clock formula, recomputed by hand from the
        // base link and the per-worker factor stream — pins both the
        // formula and the lazy recomputation
        let c = CostModel::from_preset("hetero", 4, 0.03, 9).unwrap();
        let base = LinkModel::edge();
        for step in 0..4 {
            for w in 0..4u32 {
                let u = Rng::for_stream(9 ^ LINK_SALT, w as u64, 0).uniform();
                let f = 1.0 / (1.0 + (4.0 - 1.0) * u);
                let latency = base.latency_s / f;
                let down = latency + 320_000f64 / (base.downlink_bps * f);
                let up = latency + 10_000f64 / (base.uplink_bps * f);
                let expect = down + up + c.straggler_s(step, w);
                assert_eq!(expect.to_bits(), c.arrival_s(step, w, 10_000, 320_000).to_bits());
            }
        }
    }

    #[test]
    fn hetero_compute_preset_carries_a_default_compute_term() {
        let plain = CostModel::from_preset("hetero", 4, 0.0, 2).unwrap();
        let hc = CostModel::from_preset("hetero-compute", 4, 0.0, 2).unwrap();
        for w in 0..4u32 {
            assert_eq!(plain.price(0, w, 10_000, 320_000).compute_s, 0.0);
            let cs = hc.price(0, w, 10_000, 320_000).compute_s;
            assert!(cs >= 0.02, "worker {w}: {cs}");
            // same seed, same link draws: the preset only adds compute
            assert!(hc.arrival_s(0, w, 10_000, 320_000) > plain.arrival_s(0, w, 10_000, 320_000));
        }
    }

    #[test]
    fn calibrated_compute_is_the_fit_and_monotone_in_d() {
        assert_eq!(calibrated_compute_s(0), COMPUTE_FIT_BASE_S);
        let mut prev = 0.0;
        for d in [0usize, 1_000, 100_000, 1 << 20, 10_000_000] {
            let c = calibrated_compute_s(d);
            assert_eq!(c, COMPUTE_FIT_BASE_S + d as f64 * COMPUTE_FIT_PER_ELEM_S);
            assert!(c > prev || d == 0, "fit must grow with d");
            assert!(c.is_finite() && c > 0.0);
            prev = c;
        }
    }

    #[test]
    fn compute_auto_installs_the_calibrated_term() {
        let mut cfg = TrainConfig::default();
        cfg.link = "hetero".into();
        cfg.set("compute", "auto").unwrap();
        cfg.validate().unwrap();
        let d = 50_000;
        let auto = CostSpec::from_train_cfg_for_dim(&cfg, 4, d).unwrap().build();
        // bit-identical to spelling the fitted value out explicitly
        let mut explicit = cfg.clone();
        explicit.compute_auto = false;
        explicit.compute = calibrated_compute_s(d);
        let want = CostSpec::from_train_cfg(&explicit, 4).unwrap().build();
        for w in 0..4u32 {
            assert_eq!(
                auto.arrival_s(0, w, 10_000, 320_000).to_bits(),
                want.arrival_s(0, w, 10_000, 320_000).to_bits()
            );
            assert_eq!(auto.price(0, w, 0, 0).compute_s, calibrated_compute_s(d));
        }
        // the spread knob composes with auto
        cfg.set("compute_spread", "4").unwrap();
        cfg.validate().unwrap();
        let spread = CostSpec::from_train_cfg_for_dim(&cfg, 4, d).unwrap().build();
        let cs: Vec<f64> = (0..4).map(|w| spread.price(0, w, 0, 0).compute_s).collect();
        let base = calibrated_compute_s(d);
        assert!(cs.iter().all(|&c| (base..=4.0 * base + 1e-12).contains(&c)), "{cs:?}");
        assert!(cs.windows(2).any(|p| p[0] != p[1]), "spread drew no spread: {cs:?}");
        // an explicit compute > 0 still wins over the preset; auto=false
        // with compute=0 leaves the preset's built-in term in place
        let mut plain = TrainConfig::default();
        plain.link = "hetero-compute".into();
        let m = CostSpec::from_train_cfg_for_dim(&plain, 4, d).unwrap().build();
        assert!(m.price(0, 0, 0, 0).compute_s >= 0.02);
        // the dimension-less shorthand is the d = 0 fit
        let short = CostSpec::from_train_cfg(&cfg, 4).unwrap().build();
        assert!(short.price(0, 0, 0, 0).compute_s >= COMPUTE_FIT_BASE_S);
    }

    #[test]
    fn straggler_delays_nonnegative_with_sane_mean() {
        let c = CostModel::from_preset("datacenter", 4, 0.05, 11).unwrap();
        let mut sum = 0.0;
        let n = 2000;
        for step in 0..n {
            for w in 0..4u32 {
                let s = c.straggler_s(step, w);
                assert!(s >= 0.0);
                sum += s;
            }
        }
        let mean = sum / (4 * n) as f64;
        assert!((mean - 0.05).abs() < 0.01, "empirical mean {mean}");
        // disabled stragglers are exactly zero
        let c0 = CostModel::from_preset("datacenter", 4, 0.0, 11).unwrap();
        assert_eq!(c0.straggler_s(0, 0), 0.0);
    }

    #[test]
    fn clock_monotone_under_advance() {
        let mut c = CostModel::from_preset("edge", 2, 0.0, 1).unwrap();
        let mut prev = c.now_s();
        for step in 0..10 {
            let dur = c.arrival_s(step, 0, 1000, 1000);
            let now = c.advance(dur);
            assert!(now >= prev);
            assert!(now > prev, "positive-latency rounds must advance time");
            prev = now;
        }
        // negative durations are clamped, never rewinding time
        let before = c.now_s();
        assert_eq!(c.advance(-5.0), before);
    }
}
