//! Deterministic cluster **cost model**: the promotion of the PR 2
//! `VirtualClock` from a pure *transfer*-time source into a full
//! per-step cost model. A worker's simulated arrival time is now
//!
//! ```text
//! arrival = download + compute + upload + straggler
//! ```
//!
//! where `download`/`upload` come from per-worker heterogeneous
//! [`LinkModel`]s, **compute** is a new per-worker gradient-computation
//! term (base seconds × a seeded per-worker slowdown factor), and the
//! straggler term is the seeded exponential delay of PR 2. Adaptive
//! participation policies ([`crate::engine::policy`]) therefore optimize
//! simulated *step* time, not transfer time alone.
//!
//! Determinism contract (unchanged from the clock): [`CostModel::arrival_s`]
//! is a pure function of `(step, worker, up_bits, down_bits)` — it never
//! depends on the order messages were physically gathered (permutation
//! stability) or on wall time. All per-worker draws (link heterogeneity,
//! compute slowdown) come once per worker from dedicated `(seed, worker)`
//! streams, and the straggler draw from the `(seed, worker, step)`
//! stream, so repeated runs replay exactly.
//!
//! Bit-compatibility contract: with a zero compute term the arrival time
//! is **bit-identical** to the pre-cost-model `VirtualClock` — the three
//! original presets (`datacenter`, `edge`, `hetero`) carry no compute
//! term, so every pre-existing trajectory replays unchanged.

use super::LinkModel;
use crate::tensor::Rng;
use anyhow::{bail, Result};

/// Stream salt for per-worker link heterogeneity factors.
const LINK_SALT: u64 = 0x11_4B5;
/// Stream salt for per-(worker, step) straggler delays.
const STRAGGLER_SALT: u64 = 0x57_4A66;
/// Stream salt for per-worker compute slowdown factors.
const COMPUTE_SALT: u64 = 0xC0_4B7E;

/// Known presets for the `link` config knob.
pub fn preset_names() -> &'static [&'static str] {
    &["datacenter", "edge", "hetero", "hetero-compute"]
}

/// Simulated per-step cost source for the round engine: heterogeneous
/// links + per-worker compute + seeded stragglers, plus the run's
/// simulated wall-clock accumulator.
#[derive(Clone, Debug)]
pub struct CostModel {
    links: Vec<LinkModel>,
    /// per-worker gradient-compute seconds (0 = communication only)
    compute_s: Vec<f64>,
    straggler_mean_s: f64,
    seed: u64,
    now_s: f64,
}

impl CostModel {
    /// Per-worker links derived from `base`: worker `w`'s bandwidths are
    /// scaled by a deterministic factor in `[1/spread, 1]` (and its
    /// latency inflated by the inverse), drawn once per worker from the
    /// `(seed, worker)` stream. `spread <= 1` means homogeneous links.
    /// The compute term starts at zero; see [`CostModel::with_compute`].
    pub fn new(
        base: &LinkModel,
        workers: usize,
        spread: f64,
        straggler_mean_s: f64,
        seed: u64,
    ) -> Self {
        let spread = spread.max(1.0);
        let links = (0..workers)
            .map(|w| {
                let f = if spread > 1.0 {
                    let u = Rng::for_stream(seed ^ LINK_SALT, w as u64, 0).uniform();
                    1.0 / (1.0 + (spread - 1.0) * u)
                } else {
                    1.0
                };
                LinkModel {
                    uplink_bps: base.uplink_bps * f,
                    downlink_bps: base.downlink_bps * f,
                    latency_s: base.latency_s / f,
                }
            })
            .collect();
        CostModel {
            links,
            compute_s: vec![0.0; workers],
            straggler_mean_s: straggler_mean_s.max(0.0),
            seed,
            now_s: 0.0,
        }
    }

    /// Set the per-worker gradient-compute term: worker `w` takes
    /// `base_s * f_w` seconds per step, with `f_w` a deterministic
    /// slowdown factor in `[1, spread]` drawn once per worker from the
    /// `(seed, worker)` compute stream (`spread <= 1` = homogeneous
    /// compute). `base_s <= 0` clears the term.
    pub fn with_compute(mut self, base_s: f64, spread: f64) -> Self {
        let base_s = base_s.max(0.0);
        let spread = spread.max(1.0);
        for (w, c) in self.compute_s.iter_mut().enumerate() {
            let f = if spread > 1.0 {
                let u = Rng::for_stream(self.seed ^ COMPUTE_SALT, w as u64, 0).uniform();
                1.0 + (spread - 1.0) * u
            } else {
                1.0
            };
            *c = base_s * f;
        }
        self
    }

    /// Build from a named preset ([`preset_names`]):
    ///
    /// * `"datacenter"` / `"edge"` — homogeneous links, no compute term
    /// * `"hetero"` — edge base with a 4x per-worker bandwidth spread
    /// * `"hetero-compute"` — `hetero` plus a default compute term
    ///   (20 ms base, 4x per-worker spread), so the arrival elbow is
    ///   shaped by compute *and* transfer. An explicit `compute` config
    ///   knob replaces this whole term, spread included — pass
    ///   `compute_spread` too to keep heterogeneity
    ///
    /// Unknown names are a loud, centralized error listing the known
    /// presets — call sites must not re-implement the message.
    pub fn from_preset(
        name: &str,
        workers: usize,
        straggler_mean_s: f64,
        seed: u64,
    ) -> Result<Self> {
        let (base, spread, compute) = match name {
            "datacenter" => (LinkModel::datacenter(), 1.0, None),
            "edge" => (LinkModel::edge(), 1.0, None),
            "hetero" => (LinkModel::edge(), 4.0, None),
            "hetero-compute" => (LinkModel::edge(), 4.0, Some((0.02, 4.0))),
            _ => bail!("unknown link preset {name:?} (known: {:?})", preset_names()),
        };
        let model = Self::new(&base, workers, spread, straggler_mean_s, seed);
        Ok(match compute {
            Some((base_s, sp)) => model.with_compute(base_s, sp),
            None => model,
        })
    }

    pub fn workers(&self) -> usize {
        self.links.len()
    }

    pub fn link(&self, worker: u32) -> &LinkModel {
        &self.links[worker as usize]
    }

    /// Worker `w`'s per-step gradient-compute seconds.
    pub fn compute_s(&self, worker: u32) -> f64 {
        self.compute_s[worker as usize]
    }

    /// Exponential straggler delay for `(worker, step)` via inverse-CDF
    /// sampling on the dedicated stream; 0 when stragglers are disabled.
    pub fn straggler_s(&self, step: u64, worker: u32) -> f64 {
        if self.straggler_mean_s <= 0.0 {
            return 0.0;
        }
        let u = Rng::for_stream(self.seed ^ STRAGGLER_SALT, worker as u64, step).uniform();
        -self.straggler_mean_s * (1.0 - u).ln()
    }

    /// Simulated arrival time — relative to the round start — of worker
    /// `w`'s uplink message of `up_bits`: download the `down_bits`
    /// params broadcast over its own link, compute the gradient, upload,
    /// plus the straggler draw. Pure in `(step, worker, up_bits,
    /// down_bits)`; bit-identical to the pre-cost-model clock when the
    /// compute term is zero.
    pub fn arrival_s(&self, step: u64, worker: u32, up_bits: u64, down_bits: u64) -> f64 {
        let l = &self.links[worker as usize];
        let down = l.latency_s + down_bits as f64 / l.downlink_bps;
        let up = l.latency_s + up_bits as f64 / l.uplink_bps;
        down + self.compute_s[worker as usize] + up + self.straggler_s(step, worker)
    }

    /// Advance simulated time by one round's duration.
    pub fn advance(&mut self, round_s: f64) -> f64 {
        self.now_s += round_s.max(0.0);
        self.now_s
    }

    /// Simulated wall-clock since the run started.
    pub fn now_s(&self) -> f64 {
        self.now_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_build_and_unknown_rejected_loudly() {
        for name in preset_names() {
            let c = CostModel::from_preset(name, 4, 0.0, 1).unwrap();
            assert_eq!(c.workers(), 4);
        }
        let err = CostModel::from_preset("carrier-pigeon", 4, 0.0, 1).unwrap_err().to_string();
        assert!(err.contains("carrier-pigeon"), "{err}");
        for name in preset_names() {
            assert!(err.contains(name), "error must list {name}: {err}");
        }
    }

    #[test]
    fn arrival_is_pure_and_deterministic() {
        let a = CostModel::from_preset("hetero", 8, 0.02, 7).unwrap();
        let b = CostModel::from_preset("hetero", 8, 0.02, 7).unwrap();
        for step in 0..5 {
            for w in 0..8u32 {
                let t1 = a.arrival_s(step, w, 10_000, 320_000);
                let t2 = a.arrival_s(step, w, 10_000, 320_000);
                let t3 = b.arrival_s(step, w, 10_000, 320_000);
                assert_eq!(t1.to_bits(), t2.to_bits());
                assert_eq!(t1.to_bits(), t3.to_bits());
                assert!(t1 > 0.0);
            }
        }
        // different seed shifts the straggler draws
        let c = CostModel::from_preset("hetero", 8, 0.02, 8).unwrap();
        assert_ne!(
            a.arrival_s(0, 0, 10_000, 320_000).to_bits(),
            c.arrival_s(0, 0, 10_000, 320_000).to_bits()
        );
    }

    #[test]
    fn hetero_spread_slows_some_workers() {
        let hom = CostModel::from_preset("edge", 8, 0.0, 3).unwrap();
        let het = CostModel::from_preset("hetero", 8, 0.0, 3).unwrap();
        let t_hom: Vec<f64> = (0..8).map(|w| hom.arrival_s(0, w, 1_000_000, 0)).collect();
        let t_het: Vec<f64> = (0..8).map(|w| het.arrival_s(0, w, 1_000_000, 0)).collect();
        // homogeneous: identical; heterogeneous: a real spread, never faster
        assert!(t_hom.windows(2).all(|p| p[0] == p[1]));
        let (min, max) = t_het
            .iter()
            .fold((f64::INFINITY, 0.0f64), |(lo, hi), &t| (lo.min(t), hi.max(t)));
        assert!(max > 1.5 * min, "spread too small: {min}..{max}");
        assert!(min >= t_hom[0], "hetero workers cannot beat the base link");
    }

    #[test]
    fn compute_term_is_additive_monotone_and_per_worker() {
        let base = CostModel::from_preset("hetero", 8, 0.0, 5).unwrap();
        let slow = base.clone().with_compute(0.05, 1.0);
        let slower = base.clone().with_compute(0.10, 1.0);
        for w in 0..8u32 {
            let t0 = base.arrival_s(0, w, 10_000, 320_000);
            let t1 = slow.arrival_s(0, w, 10_000, 320_000);
            let t2 = slower.arrival_s(0, w, 10_000, 320_000);
            // homogeneous compute: exactly additive, monotone in base_s
            assert!((t1 - t0 - 0.05).abs() < 1e-12, "worker {w}: {t0} {t1}");
            assert!(t2 > t1 && t1 > t0);
            assert_eq!(slow.compute_s(w), 0.05);
        }
        // spread > 1: every worker in [base, base*spread], not all equal
        let spread = base.with_compute(0.05, 4.0);
        let cs: Vec<f64> = (0..8).map(|w| spread.compute_s(w)).collect();
        assert!(cs.iter().all(|&c| (0.05..=0.2 + 1e-12).contains(&c)), "{cs:?}");
        assert!(cs.windows(2).any(|p| p[0] != p[1]), "compute spread drew no spread: {cs:?}");
        // the draw is per worker, fixed across steps (pure)
        for w in 0..8u32 {
            assert_eq!(
                spread.arrival_s(3, w, 10_000, 0).to_bits(),
                spread.arrival_s(3, w, 10_000, 0).to_bits()
            );
        }
    }

    #[test]
    fn zero_compute_matches_link_only_formula_bitwise() {
        // the pre-cost-model clock formula, recomputed by hand
        let c = CostModel::from_preset("hetero", 4, 0.03, 9).unwrap();
        for step in 0..4 {
            for w in 0..4u32 {
                let l = c.link(w);
                let down = l.latency_s + 320_000f64 / l.downlink_bps;
                let up = l.latency_s + 10_000f64 / l.uplink_bps;
                let expect = down + up + c.straggler_s(step, w);
                assert_eq!(expect.to_bits(), c.arrival_s(step, w, 10_000, 320_000).to_bits());
            }
        }
    }

    #[test]
    fn hetero_compute_preset_carries_a_default_compute_term() {
        let plain = CostModel::from_preset("hetero", 4, 0.0, 2).unwrap();
        let hc = CostModel::from_preset("hetero-compute", 4, 0.0, 2).unwrap();
        for w in 0..4u32 {
            assert_eq!(plain.compute_s(w), 0.0);
            assert!(hc.compute_s(w) >= 0.02, "worker {w}: {}", hc.compute_s(w));
            // same seed, same link draws: the preset only adds compute
            assert!(hc.arrival_s(0, w, 10_000, 320_000) > plain.arrival_s(0, w, 10_000, 320_000));
        }
    }

    #[test]
    fn straggler_delays_nonnegative_with_sane_mean() {
        let c = CostModel::from_preset("datacenter", 4, 0.05, 11).unwrap();
        let mut sum = 0.0;
        let n = 2000;
        for step in 0..n {
            for w in 0..4u32 {
                let s = c.straggler_s(step, w);
                assert!(s >= 0.0);
                sum += s;
            }
        }
        let mean = sum / (4 * n) as f64;
        assert!((mean - 0.05).abs() < 0.01, "empirical mean {mean}");
        // disabled stragglers are exactly zero
        let c0 = CostModel::from_preset("datacenter", 4, 0.0, 11).unwrap();
        assert_eq!(c0.straggler_s(0, 0), 0.0);
    }

    #[test]
    fn clock_monotone_under_advance() {
        let mut c = CostModel::from_preset("edge", 2, 0.0, 1).unwrap();
        let mut prev = c.now_s();
        for step in 0..10 {
            let dur = c.arrival_s(step, 0, 1000, 1000);
            let now = c.advance(dur);
            assert!(now >= prev);
            assert!(now > prev, "positive-latency rounds must advance time");
            prev = now;
        }
        // negative durations are clamped, never rewinding time
        let before = c.now_s();
        assert_eq!(c.advance(-5.0), before);
    }
}
