//! Lazy **population** handle + heap-driven round simulator: virtual
//! rounds over populations far beyond what the full engine (which
//! carries per-worker transport handlers, encoders, and gradients) can
//! instantiate — the regime where partial participation over 10⁵–10⁶
//! clients actually lives.
//!
//! [`Population`] is the O(1) handle: a [`CostModel`] plus its declared
//! size M. Nothing per-worker exists until a round asks for a specific
//! worker's arrival, and only the round's **active** participants are
//! ever priced — a sampled round over a million workers builds a heap of
//! the drawn cohort and touches nobody else.
//!
//! [`RoundSim`] runs the round engine's virtual-mode protocol —
//! policy draw → event-heap arrivals → [`ArrivalView`] close →
//! on-time/late partition → stale resolution → ack staging → bit
//! accounting → clock advance — with a **constant-size message model**
//! (every uplink reply is `up_bits`, the broadcast `down_bits`): the
//! engine minus gradients. Decision-for-decision it matches
//! [`crate::engine::RoundEngine::run_round`] on the same config
//! (`tests/prop_scale.rs` pins arrivals, close, stale weights, acks,
//! and bit totals against the engine at every M the engine can hold),
//! while memory stays O(active participants + pending stragglers).

use anyhow::{bail, Result};

use crate::ef::{AckEntry, AckStatus, AggKind};
use crate::engine::policy::{ArrivalView, CloseRule, ParticipationPolicy, StaleAction};
use crate::engine::report::TierStats;
use crate::transport::tree::TreePlan;

use super::cost::CostModel;
use super::event::{Event, EventHeap, HeapArrivals};

/// The aggregation topology a [`RoundSim`] prices.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Topology {
    /// every worker uplinks straight to the leader (the default)
    Star,
    /// leaves → sub-aggregators → leader ([`TreePlan`] grouping:
    /// `fanout` leaves per group, `0` = auto ~√M). Every reply pays one
    /// extra relay hop ([`CostModel::relay_hop_s`]); with
    /// `replication = r > 1` each logical leaf is backed by `r` physical
    /// candidates (the cost model must then hold `logical_m × r`
    /// workers) and the **first** candidate arrival wins — the coded
    /// leaf shards are interchangeable, so only timing changes.
    Tree { fanout: usize, replication: usize },
}

/// A simulated worker population behind one lazy [`CostModel`]: size M,
/// zero per-worker state. Prices a round's active participants into an
/// [`EventHeap`] on demand.
pub struct Population {
    cost: CostModel,
}

impl Population {
    pub fn new(cost: CostModel) -> Self {
        Population { cost }
    }

    /// Population size M (worker ids are `0..size`).
    pub fn size(&self) -> usize {
        self.cost.workers()
    }

    pub fn cost(&self) -> &CostModel {
        &self.cost
    }

    pub fn cost_mut(&mut self) -> &mut CostModel {
        &mut self.cost
    }

    /// Price this round's active participants into a min-heap of
    /// arrival events: O(active) work and memory, whatever M is.
    pub fn arrivals(&self, step: u64, parts: &[u32], up_bits: u64, down_bits: u64) -> EventHeap {
        let mut heap = EventHeap::with_capacity(parts.len());
        for &w in parts {
            heap.push(Event { at_s: self.cost.arrival_s(step, w, up_bits, down_bits), worker: w });
        }
        heap
    }

    /// Tree-topology arrivals with coded leaf redundancy: logical leaf
    /// `w` is backed by the `replication` physical workers
    /// `w*r .. w*r+r`, the earliest of which wins, and every reply pays
    /// one relay hop through its sub-aggregator. Still O(active) — only
    /// `replication ×` the drawn cohort is ever priced.
    pub fn arrivals_coded(
        &self,
        step: u64,
        parts: &[u32],
        up_bits: u64,
        down_bits: u64,
        replication: usize,
    ) -> EventHeap {
        let r = replication.max(1) as u32;
        let hop = self.cost.relay_hop_s(up_bits);
        let mut heap = EventHeap::with_capacity(parts.len());
        for &w in parts {
            let mut best = f64::INFINITY;
            for rho in 0..r {
                let t = self.cost.arrival_s(step, w * r + rho, up_bits, down_bits);
                if t < best {
                    best = t;
                }
            }
            heap.push(Event { at_s: best + hop, worker: w });
        }
        heap
    }
}

/// What one simulated round did: the simulator constructs the same
/// [`crate::engine::report::RoundReport`] the live engine does (the
/// unified report). A constant-bit simulation defines no losses and no
/// real-time recovery, so those fields stay at their `Default`; the
/// simulator additionally fills `acks` (the next broadcast's ack
/// stream, for protocol-equivalence tests) and — on tree topologies —
/// `tiers`.
pub type SimRoundReport = crate::engine::report::RoundReport;

/// Heap-driven virtual round loop over a [`Population`]: the engine's
/// round protocol at O(active) memory with a constant-size message
/// model. See the module docs for the equivalence contract.
pub struct RoundSim {
    population: Population,
    policy: Box<dyn ParticipationPolicy>,
    agg: AggKind,
    topology: Topology,
    up_bits: u64,
    down_bits: u64,
    /// late messages awaiting resolution: `(worker, sent_step)`
    pending: Vec<(u32, u64)>,
    /// `Some(bits)` = `reduce = "tier"` pricing: each active group's
    /// upward hop carries one dense partial of this many bits instead
    /// of its leaves' payloads verbatim (`None` = reduce at the root)
    reduced_bits: Option<u64>,
    total_bits: u64,
    step: u64,
}

impl RoundSim {
    pub fn new(
        cost: CostModel,
        policy: Box<dyn ParticipationPolicy>,
        agg: AggKind,
        up_bits: u64,
        down_bits: u64,
    ) -> Self {
        RoundSim {
            population: Population::new(cost),
            policy,
            agg,
            topology: Topology::Star,
            up_bits,
            down_bits,
            pending: Vec::new(),
            reduced_bits: None,
            total_bits: 0,
            step: 0,
        }
    }

    /// Price `reduce = "tier"` (builder-style, strictly opt-in): the
    /// root-tier `forwarded_bits` in the report becomes
    /// `active_groups × reduced_bits` — one dense partial per group —
    /// instead of the participants' payloads verbatim. Leaf-tier
    /// pricing, round latency, and the charge-once bit total are
    /// untouched: the leaves still transmit every payload (that is what
    /// the leader meters, bit-identically to `reduce = "root"`), only
    /// the sub→root ingress shrinks. Requires a tree topology, so call
    /// it after [`Self::with_topology`].
    pub fn with_reduce(mut self, reduced_bits: u64) -> Result<Self> {
        if !matches!(self.topology, Topology::Tree { .. }) {
            bail!("tier reduction needs a relay tier to reduce at (with_topology first)");
        }
        self.reduced_bits = Some(reduced_bits);
        Ok(self)
    }

    /// Switch the simulated aggregation topology (builder-style;
    /// default [`Topology::Star`]). For a tree with `replication = r`,
    /// the cost model must hold `logical_m × r` workers — physical
    /// candidate `w*r + ρ` backs logical leaf `w`.
    pub fn with_topology(mut self, topology: Topology) -> Result<Self> {
        if let Topology::Tree { fanout, replication } = topology {
            if replication == 0 {
                bail!("tree replication must be >= 1");
            }
            let phys = self.population.size();
            if phys % replication != 0 {
                bail!(
                    "population of {phys} workers is not divisible by replication {replication}"
                );
            }
            // validates the leaf/fanout arithmetic up front
            TreePlan::resolve(phys / replication, fanout)?;
        }
        self.topology = topology;
        Ok(self)
    }

    pub fn topology(&self) -> Topology {
        self.topology
    }

    /// Logical leaf count the policy draws over: the population size on
    /// a star; physical workers ÷ replication on a coded tree.
    pub fn logical_m(&self) -> usize {
        match self.topology {
            Topology::Star => self.population.size(),
            Topology::Tree { replication, .. } => self.population.size() / replication.max(1),
        }
    }

    pub fn population(&self) -> &Population {
        &self.population
    }

    pub fn total_bits(&self) -> u64 {
        self.total_bits
    }

    pub fn sim_now_s(&self) -> f64 {
        self.population.cost().now_s()
    }

    /// Next round index.
    pub fn step_index(&self) -> u64 {
        self.step
    }

    /// One simulated round. Mirrors the engine's virtual path decision
    /// for decision: same close deadline, same on-time/late partition
    /// (ties at the deadline on time), same stale resolution order
    /// (ascending `(sent_step, worker)`, per-worker supersede dedupe for
    /// `Fresh`, full weight for `Accumulate`), same ack order, same
    /// charge-once bit accounting.
    pub fn run_round(&mut self) -> Result<SimRoundReport> {
        let step = self.step;
        let m = self.logical_m();
        let parts = self.policy.draw(step, m);
        let heap = match self.topology {
            Topology::Star => {
                self.population.arrivals(step, &parts, self.up_bits, self.down_bits)
            }
            Topology::Tree { replication, .. } => self.population.arrivals_coded(
                step,
                &parts,
                self.up_bits,
                self.down_bits,
                replication,
            ),
        };
        let mut view = HeapArrivals::new(heap, m);
        let active = view.active();
        let deadline = match self.policy.close_at(step, &mut view) {
            CloseRule::AtTime(t) => t,
            CloseRule::Count(0) => {
                bail!("policy {:?} returned CloseRule::Count(0)", self.policy.name())
            }
            CloseRule::Count(k) => {
                if active == 0 {
                    0.0
                } else {
                    view.nth(if k < active { k - 1 } else { active - 1 })
                        .expect("index < active participants")
                        .at_s
                }
            }
        };

        // partition: the popped prefix is ascending and every event
        // still in the heap is >= the prefix max, so splitting the
        // prefix at the deadline and tie-popping the heap is exact
        let (prefix, mut rest) = view.into_parts();
        let mut on_time: Vec<u32> = Vec::new();
        let mut late: Vec<u32> = Vec::new();
        let earliest = prefix
            .first()
            .map(|a| a.at_s)
            .or_else(|| rest.peek().map(|e| e.at_s))
            .unwrap_or(f64::INFINITY);
        for a in &prefix {
            if a.at_s <= deadline {
                on_time.push(a.worker);
            } else {
                late.push(a.worker);
            }
        }
        while let Some(e) = rest.peek() {
            if e.at_s > deadline {
                break;
            }
            on_time.push(rest.pop().expect("peeked event exists").worker);
        }
        late.extend(rest.drain_workers());
        on_time.sort_unstable();
        late.sort_unstable();

        // same zero-replies contract as the engine: every sane close
        // rule admits at least the earliest arrival
        if on_time.is_empty() && active > 0 {
            bail!(
                "policy {:?} closed step {step} at {deadline}s, before the earliest arrival \
                 ({earliest}s) — a round cannot close on zero replies",
                self.policy.name()
            );
        }

        // resolve the stale buffer, then this round's replies — the
        // engine's exact order and accounting with constant-size
        // messages (each transmission charged once, at resolution)
        let mut acks: Vec<(u32, AckEntry)> = Vec::new();
        fn stage(acks: &mut Vec<(u32, AckEntry)>, w: u32, sent_step: u64, s: AckStatus, wt: f32) {
            acks.push((w, AckEntry { sent_step, status: s, weight: wt }));
        }
        let mut resolve = std::mem::take(&mut self.pending);
        resolve.sort_unstable_by_key(|&(w, s)| (s, w));
        let mut applied_msgs = 0u64;
        let mut applied_stale = 0usize;
        let mut dropped_stale = 0usize;
        let mut dropped_bits = 0u64;
        for (w, sent) in resolve {
            match self.agg {
                AggKind::Accumulate => {
                    stage(&mut acks, w, sent, AckStatus::Applied, 1.0);
                    applied_msgs += 1;
                    applied_stale += 1;
                }
                AggKind::Fresh => {
                    let superseded = on_time.binary_search(&w).is_ok();
                    let age = step.saturating_sub(sent).max(1);
                    let action = if superseded {
                        StaleAction::Drop
                    } else {
                        self.policy.stale_weight(age)
                    };
                    match action {
                        StaleAction::Drop => {
                            stage(&mut acks, w, sent, AckStatus::Dropped, 0.0);
                            dropped_bits += self.up_bits;
                            dropped_stale += 1;
                        }
                        StaleAction::Apply(weight) => {
                            stage(&mut acks, w, sent, AckStatus::Applied, weight);
                            applied_msgs += 1;
                            applied_stale += 1;
                        }
                    }
                }
            }
        }
        for &w in &on_time {
            stage(&mut acks, w, step, AckStatus::Applied, 1.0);
            applied_msgs += 1;
        }
        for &w in &late {
            stage(&mut acks, w, step, AckStatus::Deferred, 0.0);
            self.pending.push((w, step));
        }
        acks.sort_by_key(|(w, a)| (*w, a.sent_step));

        let bits = applied_msgs * self.up_bits + dropped_bits;
        self.total_bits += bits;
        let sim_now_s = self.population.cost_mut().advance(deadline);
        self.step += 1;
        let tiers = match self.topology {
            Topology::Star => Vec::new(),
            Topology::Tree { fanout, .. } => {
                tier_stats(&TreePlan::resolve(m, fanout)?, &parts, self.up_bits, self.reduced_bits)
            }
        };
        Ok(SimRoundReport {
            step,
            participants: parts.len(),
            on_time: on_time.len(),
            late: late.len(),
            applied_stale,
            dropped_stale,
            bits,
            total_bits: self.total_bits,
            sim_round_s: deadline,
            sim_now_s,
            acks,
            tiers,
            // no losses, no real-time recovery in a constant-bit sim
            ..Default::default()
        })
    }

    /// Resolve the deferred buffer outside the round loop, exactly like
    /// the engine's drain: `Accumulate` increments are absorbed
    /// (applied), stale `Fresh` gradients discarded — transmitted either
    /// way, so every pending message's bits join the total exactly once.
    /// Returns `(absorbed, discarded)`. Idempotent.
    pub fn drain_pending(&mut self) -> (usize, usize) {
        let pending = std::mem::take(&mut self.pending);
        if pending.is_empty() {
            return (0, 0);
        }
        self.total_bits += pending.len() as u64 * self.up_bits;
        match self.agg {
            AggKind::Accumulate => (pending.len(), 0),
            AggKind::Fresh => (0, pending.len()),
        }
    }
}

/// Per-tier relay statistics of one tree round, leaf tier first. Under
/// `reduce = "root"` (`reduced_bits = None`) the bits are conserved
/// through the relay (batch frames carry leaf replies verbatim), so
/// both tiers forward the full participant payload — the tree's win is
/// **fan-in**: the root waits on the active sub-aggregators, not on
/// every leaf. Under `reduce = "tier"` the root tier instead forwards
/// one `reduced_bits` partial per active group: fan-in AND ingress
/// shrink. `parts` must be ascending (policy draws are), so group
/// owners arrive run-length contiguous.
fn tier_stats(
    plan: &TreePlan,
    parts: &[u32],
    up_bits: u64,
    reduced_bits: Option<u64>,
) -> Vec<TierStats> {
    let mut active_groups = 0usize;
    let mut max_fan = 0usize;
    let mut cur: Option<u32> = None;
    let mut n = 0usize;
    for &w in parts {
        let g = plan.owner(w);
        if Some(g) != cur {
            if n > max_fan {
                max_fan = n;
            }
            active_groups += 1;
            cur = Some(g);
            n = 0;
        }
        n += 1;
    }
    if n > max_fan {
        max_fan = n;
    }
    let forwarded_bits = parts.len() as u64 * up_bits;
    let root_ingress = match reduced_bits {
        Some(rb) => active_groups as u64 * rb,
        None => forwarded_bits,
    };
    vec![
        TierStats { fan_in: max_fan, forwarded_bits },
        TierStats { fan_in: active_groups, forwarded_bits: root_ingress },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::policy::{
        AdaptiveQuorum, ClientSampling, FixedQuorum, FullSync, StaleWeight,
    };
    use crate::netsim::CostSpec;

    const UP: u64 = 32 * 16;
    const DOWN: u64 = 32 * 64;

    fn sim(
        m: usize,
        policy: Box<dyn ParticipationPolicy>,
        agg: AggKind,
        straggler: f64,
    ) -> RoundSim {
        let cost =
            CostSpec::preset("hetero").unwrap().workers(m).straggler(straggler).seed(7).build();
        RoundSim::new(cost, policy, agg, UP, DOWN)
    }

    #[test]
    fn fullsync_round_hears_everyone_and_charges_once() {
        let mut s = sim(8, Box::new(FullSync::new(StaleWeight::Damp)), AggKind::Fresh, 0.0);
        let r = s.run_round().unwrap();
        assert_eq!((r.participants, r.on_time, r.late), (8, 8, 0));
        assert_eq!((r.applied_stale, r.dropped_stale), (0, 0));
        assert_eq!(r.bits, 8 * UP);
        assert_eq!(r.total_bits, s.total_bits());
        assert!(r.sim_round_s > 0.0);
        assert_eq!(r.sim_now_s, s.sim_now_s());
        assert_eq!(r.acks.len(), 8);
        assert!(r.acks.iter().all(|(_, a)| a.status == AckStatus::Applied && a.weight == 1.0));
        assert_eq!(s.drain_pending(), (0, 0));
    }

    #[test]
    fn quorum_defers_then_resolves_with_engine_accounting() {
        let k = 3;
        let mut s =
            sim(6, Box::new(FixedQuorum::new(k, StaleWeight::Damp)), AggKind::Fresh, 5.0);
        let r0 = s.run_round().unwrap();
        assert_eq!(r0.on_time + r0.late, 6);
        assert!(r0.on_time >= k, "ties at the deadline are on time");
        let r1 = s.run_round().unwrap();
        // every round-0 late message resolves in round 1
        assert_eq!(r1.applied_stale + r1.dropped_stale, r0.late);
        let resolved = (r0.on_time + r1.applied_stale + r1.dropped_stale + r1.on_time) as u64;
        assert_eq!(r1.total_bits, resolved * UP);
        assert!(r1.sim_now_s > r0.sim_now_s);
        // drain charges the round-1 stragglers (discarded under Fresh)
        let (absorbed, discarded) = s.drain_pending();
        assert_eq!((absorbed, discarded), (0, r1.late));
        assert_eq!(s.total_bits(), (resolved + r1.late as u64) * UP);
        assert_eq!(s.drain_pending(), (0, 0), "drain is idempotent");
    }

    #[test]
    fn accumulate_resolves_stale_at_full_weight_and_absorbs_on_drain() {
        let mut s =
            sim(6, Box::new(FixedQuorum::new(2, StaleWeight::Damp)), AggKind::Accumulate, 5.0);
        let r0 = s.run_round().unwrap();
        let r1 = s.run_round().unwrap();
        assert_eq!(r1.applied_stale, r0.late);
        assert_eq!(r1.dropped_stale, 0, "increments are never dropped");
        for (_, a) in r1.acks.iter().filter(|(_, a)| a.sent_step == 0) {
            assert_eq!((a.status, a.weight), (AckStatus::Applied, 1.0));
        }
        let (absorbed, discarded) = s.drain_pending();
        assert_eq!((absorbed, discarded), (r1.late, 0));
    }

    #[test]
    fn sampled_round_prices_only_the_cohort() {
        let m = 100_000;
        let frac = 256.0 / m as f32;
        let mut s =
            sim(m, Box::new(ClientSampling::new(frac, 7, StaleWeight::Damp)), AggKind::Fresh, 0.02);
        let r = s.run_round().unwrap();
        assert_eq!(r.participants, 256);
        assert_eq!(r.on_time, 256, "sampling waits for every drawn client");
        assert_eq!(r.bits, 256 * UP);
    }

    #[test]
    fn adaptive_replays_bitwise_and_beats_nobody_to_zero() {
        let runs: Vec<SimRoundReport> = (0..2)
            .map(|_| {
                let mut s = sim(
                    16,
                    Box::new(AdaptiveQuorum::new(StaleWeight::Damp)),
                    AggKind::Fresh,
                    0.05,
                );
                for _ in 0..3 {
                    s.run_round().unwrap();
                }
                s.run_round().unwrap()
            })
            .collect();
        assert_eq!(runs[0].sim_now_s.to_bits(), runs[1].sim_now_s.to_bits());
        assert_eq!(runs[0].total_bits, runs[1].total_bits);
        assert_eq!(runs[0].on_time, runs[1].on_time);
        assert!(runs[0].on_time > 16 / 2, "adaptive never closes below majority");
    }

    #[test]
    fn tree_topology_prices_a_relay_hop_and_reports_tiers() {
        let mk = |topo: Option<Topology>| {
            let mut s = sim(64, Box::new(FullSync::new(StaleWeight::Damp)), AggKind::Fresh, 0.0);
            if let Some(t) = topo {
                s = s.with_topology(t).unwrap();
            }
            s.run_round().unwrap()
        };
        let star = mk(None);
        let tree = mk(Some(Topology::Tree { fanout: 0, replication: 1 }));
        assert!(tree.sim_round_s > star.sim_round_s, "the relay hop must cost time");
        assert!(star.tiers.is_empty());
        // 64 leaves, auto fanout 8 → 8 groups of 8, all active under
        // full sync: root fan-in 8 where the star's is 64
        assert_eq!(tree.tiers.len(), 2);
        assert_eq!((tree.tiers[0].fan_in, tree.tiers[1].fan_in), (8, 8));
        assert_eq!(tree.root_fan_in(), 8);
        assert_eq!(star.root_fan_in(), 64);
        // bits are conserved through the relay — the tree only cuts
        // fan-in, never the charged uplink traffic
        assert_eq!(tree.tiers[0].forwarded_bits, 64 * UP);
        assert_eq!((tree.participants, tree.on_time, tree.late), (64, 64, 0));
        assert_eq!(tree.bits, star.bits);
    }

    #[test]
    fn tier_reduce_prices_root_ingress_per_group() {
        let reduced = 32 * 64u64; // one dense d=64 partial per group
        let mk = |reduce: bool| {
            let mut s = sim(64, Box::new(FullSync::new(StaleWeight::Damp)), AggKind::Fresh, 0.0);
            s = s.with_topology(Topology::Tree { fanout: 0, replication: 1 }).unwrap();
            if reduce {
                s = s.with_reduce(reduced).unwrap();
            }
            s.run_round().unwrap()
        };
        let root = mk(false);
        let tier = mk(true);
        // everything but the root-tier ingress is byte-identical: tier
        // reduction changes where the sum happens, not what is charged
        assert_eq!(tier.sim_round_s.to_bits(), root.sim_round_s.to_bits());
        assert_eq!(tier.bits, root.bits);
        assert_eq!(tier.tiers[0].forwarded_bits, 64 * UP);
        assert_eq!(root.tiers[1].forwarded_bits, 64 * UP);
        // 8 active groups × one dense partial each
        assert_eq!(tier.tiers[1].forwarded_bits, 8 * reduced);
        assert_eq!((tier.tiers[0].fan_in, tier.tiers[1].fan_in), (8, 8));
        // a star has no tier to reduce at
        let s = sim(8, Box::new(FullSync::new(StaleWeight::Damp)), AggKind::Fresh, 0.0);
        assert!(s.with_reduce(reduced).is_err());
    }

    #[test]
    fn coded_replication_takes_the_earliest_candidate() {
        // physical population 16 = 8 logical leaves × r=2: candidates
        // 2w and 2w+1 back leaf w; the earliest wins, plus one hop
        let cost =
            CostSpec::preset("hetero").unwrap().workers(16).straggler(0.3).seed(7).build();
        let expect = (0..8u32)
            .map(|w| {
                let a = cost.arrival_s(0, 2 * w, UP, DOWN);
                let b = cost.arrival_s(0, 2 * w + 1, UP, DOWN);
                a.min(b) + cost.relay_hop_s(UP)
            })
            .fold(0.0f64, f64::max);
        let mut s = RoundSim::new(
            cost,
            Box::new(FullSync::new(StaleWeight::Damp)),
            AggKind::Fresh,
            UP,
            DOWN,
        )
        .with_topology(Topology::Tree { fanout: 2, replication: 2 })
        .unwrap();
        assert_eq!(s.logical_m(), 8);
        let r = s.run_round().unwrap();
        assert_eq!(r.participants, 8);
        assert_eq!(r.sim_round_s.to_bits(), expect.to_bits());
        // bad shapes are rejected loudly
        let cost = CostSpec::preset("edge").unwrap().workers(9).build();
        let s = RoundSim::new(
            cost,
            Box::new(FullSync::new(StaleWeight::Damp)),
            AggKind::Fresh,
            UP,
            DOWN,
        );
        assert!(s.with_topology(Topology::Tree { fanout: 2, replication: 2 }).is_err());
    }

    #[test]
    fn broken_policies_fail_as_loudly_as_in_the_engine() {
        let mut s = sim(4, Box::new(FixedQuorum::new(0, StaleWeight::Damp)), AggKind::Fresh, 0.0);
        let err = s.run_round().unwrap_err().to_string();
        assert!(err.contains("Count(0)"), "{err}");

        struct ClosesEarly;
        impl ParticipationPolicy for ClosesEarly {
            fn name(&self) -> &'static str {
                "closes-early"
            }
            fn draw(&self, _step: u64, m: usize) -> Vec<u32> {
                (0..m as u32).collect()
            }
            fn close_at(&mut self, _step: u64, _arrivals: &mut dyn ArrivalView) -> CloseRule {
                CloseRule::AtTime(-1.0)
            }
            fn close_count(&mut self, _step: u64, participants: usize) -> usize {
                participants
            }
            fn stale_weight(&self, _age: u64) -> StaleAction {
                StaleAction::Apply(1.0)
            }
        }
        let mut s = sim(4, Box::new(ClosesEarly), AggKind::Fresh, 0.0);
        let err = s.run_round().unwrap_err().to_string();
        assert!(err.contains("before the earliest arrival"), "{err}");
    }
}
