//! Deterministic RNG substrate: xoshiro256** with splitmix64 seeding.
//!
//! The paper averages every experiment over 5 seeds; reproducibility of
//! those runs (and of the MLMC level draws inside them) demands fully
//! deterministic, stream-splittable randomness. Streams are derived per
//! `(seed, worker, step)` so worker order / thread scheduling never
//! changes the numbers.

/// splitmix64 — used to expand a single u64 seed into xoshiro state.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal from Box-Muller
    spare_normal: Option<f64>,
}

impl Rng {
    /// Seed from a single u64.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Derive an independent stream for `(seed, worker, step)`.
    /// Mixing through splitmix decorrelates nearby tuples.
    pub fn for_stream(seed: u64, worker: u64, step: u64) -> Self {
        let mut sm = seed
            ^ worker.wrapping_mul(0xA24BAED4963EE407)
            ^ step.wrapping_mul(0x9FB21C651E98DF25);
        let _ = splitmix64(&mut sm);
        Self::new(splitmix64(&mut sm))
    }

    /// Derive an independent stream for `(seed, worker, step, shard)` —
    /// the sharded extension of [`Rng::for_stream`] used by the parallel
    /// compression pipeline. `shard` is mixed as `shard + 1` so shard 0
    /// does not collide with the unsharded `(seed, worker, step)` stream.
    pub fn for_shard_stream(seed: u64, worker: u64, step: u64, shard: u64) -> Self {
        let mut sm = seed
            ^ worker.wrapping_mul(0xA24BAED4963EE407)
            ^ step.wrapping_mul(0x9FB21C651E98DF25)
            ^ shard.wrapping_add(1).wrapping_mul(0xD1B54A32D192ED03);
        let _ = splitmix64(&mut sm);
        Self::new(splitmix64(&mut sm))
    }

    /// Fork `n` per-shard child streams from this stream.
    ///
    /// Consumes exactly one draw from `self` (a digest of the stream's
    /// identity and position — for the training loop that is
    /// `(seed, worker, step)` plus how far the stream has advanced),
    /// then derives shard `i`'s stream as `for_shard_stream(digest, 0, 0, i)`.
    /// The result depends only on the parent stream state and `i`, never
    /// on thread scheduling, which is what makes the sharded compressor
    /// path bit-identical for any thread count.
    pub fn shard_streams(&mut self, n: usize) -> Vec<Rng> {
        let mut out = Vec::with_capacity(n);
        self.shard_streams_into(n, &mut out);
        out
    }

    /// [`Rng::shard_streams`] into a caller-owned buffer (cleared
    /// first). Consumes the same single digest draw and derives the
    /// same child streams — bit-identical to the allocating form; used
    /// by the arena-backed compression path to keep the steady-state
    /// round allocation-free.
    pub fn shard_streams_into(&mut self, n: usize, out: &mut Vec<Rng>) {
        let digest = self.next_u64();
        out.clear();
        out.reserve(n);
        for i in 0..n as u64 {
            out.push(Self::for_shard_stream(digest, 0, 0, i));
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn uniform_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform() as f32
    }

    /// Uniform integer in [0, n) (Lemire-style rejection-free for our use).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // 128-bit multiply avoids modulo bias well below detectable levels
        (((self.next_u64() as u128) * (n as u128)) >> 64) as usize
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.spare_normal = Some(r * s);
            return r * c;
        }
    }

    /// Fill a buffer with N(0, std^2) f32s.
    pub fn fill_normal(&mut self, buf: &mut [f32], std: f32) {
        for v in buf {
            *v = self.normal() as f32 * std;
        }
    }

    /// Sample an index from an (unnormalized, non-negative) weight vector.
    /// Returns `weights.len() - 1` on accumulated-rounding fall-through.
    pub fn categorical(&mut self, weights: &[f32]) -> usize {
        debug_assert!(!weights.is_empty());
        let total: f64 = weights.iter().map(|w| *w as f64).sum();
        debug_assert!(total > 0.0, "categorical with all-zero weights");
        let mut u = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= *w as f64;
            if u < 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// k distinct indices from [0, n) via partial Fisher-Yates over a
    /// lazily-materialized permutation (O(k) memory in the map).
    pub fn choose_k(&mut self, n: usize, k: usize) -> Vec<u32> {
        let mut out = Vec::with_capacity(k);
        self.choose_k_into(n, k, &mut out);
        out
    }

    /// [`Rng::choose_k`] into a caller-owned buffer (cleared first).
    /// Same draws, same result; the lazy-permutation scratch is local
    /// here, so prefer [`Rng::choose_k_with`] (caller-owned scratch) on
    /// an allocation-free hot path.
    pub fn choose_k_into(&mut self, n: usize, k: usize, out: &mut Vec<u32>) {
        let mut swaps = Vec::new();
        self.choose_k_with(n, k, out, &mut swaps);
    }

    /// [`Rng::choose_k_into`] with caller-owned scratch for the lazy
    /// permutation (both buffers cleared first). `swaps` holds
    /// `index << 32 | value` entries kept sorted by index and probed by
    /// binary search — the lookup-only map the draw needs, minus any
    /// per-call allocation once the buffers have warmed up (RandK lends
    /// them from its [`crate::compress::ScratchArena`]). Consumes the
    /// same RNG draws and yields the same indices as [`Rng::choose_k`],
    /// bit for bit.
    pub fn choose_k_with(&mut self, n: usize, k: usize, out: &mut Vec<u32>, swaps: &mut Vec<u64>) {
        debug_assert!(k <= n);
        debug_assert!(n <= u32::MAX as usize, "indices travel as u32");
        fn probe(swaps: &[u64], i: usize) -> Result<usize, usize> {
            swaps.binary_search_by(|e| ((e >> 32) as usize).cmp(&i))
        }
        fn value(swaps: &[u64], at: Result<usize, usize>, default: usize) -> usize {
            match at {
                Ok(pos) => (swaps[pos] & 0xFFFF_FFFF) as usize,
                Err(_) => default,
            }
        }
        swaps.clear();
        out.clear();
        out.reserve(k);
        for i in 0..k {
            let j = i + self.below(n - i);
            let vi = value(swaps, probe(swaps, i), i);
            let at_j = probe(swaps, j);
            let vj = value(swaps, at_j, j);
            out.push(vj as u32);
            let entry = ((j as u64) << 32) | vi as u64;
            match at_j {
                Ok(pos) => swaps[pos] = entry,
                Err(pos) => swaps.insert(pos, entry),
            }
        }
    }

    /// Random permutation of [0, n).
    pub fn permutation(&mut self, n: usize) -> Vec<u32> {
        let mut p: Vec<u32> = (0..n as u32).collect();
        for i in (1..n).rev() {
            let j = self.below(i + 1);
            p.swap(i, j);
        }
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let a = Rng::for_stream(1, 0, 0).next_u64();
        let b = Rng::for_stream(1, 1, 0).next_u64();
        let c = Rng::for_stream(1, 0, 1).next_u64();
        assert_ne!(a, b);
        assert_ne!(a, c);
        assert_ne!(b, c);
    }

    #[test]
    fn shard_streams_differ_and_are_deterministic() {
        // distinct across the 4-tuple, including vs the 3-tuple stream
        let base = Rng::for_stream(1, 2, 3).next_u64();
        let s0 = Rng::for_shard_stream(1, 2, 3, 0).next_u64();
        let s1 = Rng::for_shard_stream(1, 2, 3, 1).next_u64();
        let t0 = Rng::for_shard_stream(1, 2, 4, 0).next_u64();
        assert_ne!(base, s0);
        assert_ne!(s0, s1);
        assert_ne!(s0, t0);
        // forked child streams replay exactly from an identical parent
        let a: Vec<u64> = Rng::for_stream(9, 1, 7)
            .shard_streams(4)
            .iter_mut()
            .map(|r| r.next_u64())
            .collect();
        let b: Vec<u64> = Rng::for_stream(9, 1, 7)
            .shard_streams(4)
            .iter_mut()
            .map(|r| r.next_u64())
            .collect();
        assert_eq!(a, b);
        let mut s = a.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 4, "shard streams collide: {a:?}");
    }

    #[test]
    fn shard_streams_into_matches_allocating_form() {
        let mut p1 = Rng::for_stream(5, 3, 11);
        let mut p2 = p1.clone();
        let a = p1.shard_streams(5);
        let mut b = Vec::new();
        p2.shard_streams_into(5, &mut b);
        for (x, y) in a.into_iter().zip(b.iter_mut()) {
            let mut x = x;
            assert_eq!(x.next_u64(), y.next_u64());
        }
        // parents advanced identically (one digest draw each)
        assert_eq!(p1.next_u64(), p2.next_u64());
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "{mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "{mean}");
        assert!((var - 1.0).abs() < 0.03, "{var}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(5);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let i = r.below(10);
            assert!(i < 10);
            seen[i] = true;
        }
        assert!(seen.iter().all(|s| *s));
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(13);
        let w = [1.0f32, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.categorical(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let frac0 = counts[0] as f64 / 40_000.0;
        assert!((frac0 - 0.25).abs() < 0.02, "{frac0}");
    }

    #[test]
    fn choose_k_distinct_and_uniformish() {
        let mut r = Rng::new(17);
        for _ in 0..50 {
            let ks = r.choose_k(100, 10);
            let mut s = ks.clone();
            s.sort_unstable();
            s.dedup();
            assert_eq!(s.len(), 10);
            assert!(ks.iter().all(|&i| i < 100));
        }
        // edge cases
        assert_eq!(r.choose_k(5, 5).len(), 5);
        assert!(r.choose_k(5, 0).is_empty());
        // coverage: over many draws every index appears
        let mut seen = [false; 20];
        for _ in 0..200 {
            for i in r.choose_k(20, 3) {
                seen[i as usize] = true;
            }
        }
        assert!(seen.iter().all(|s| *s));
    }

    #[test]
    fn choose_k_with_matches_the_allocating_forms_bit_for_bit() {
        // same seeded stream, same draws, same indices — the sorted-Vec
        // scratch is a drop-in for the map it replaced
        let mut a = Rng::new(29);
        let mut b = a.clone();
        let mut scratch = Vec::new();
        for &(n, k) in &[(100usize, 10usize), (5, 5), (5, 0), (1, 1), (64, 64)] {
            let expect = a.choose_k(n, k);
            let mut got = Vec::new();
            b.choose_k_with(n, k, &mut got, &mut scratch);
            assert_eq!(got, expect, "n={n} k={k}");
            // the parents stayed in lock-step
            assert_eq!(a.next_u64(), b.next_u64());
        }
        // warmed-up scratch is reused, not regrown per call
        let cap = scratch.capacity();
        b.choose_k_with(64, 64, &mut Vec::new(), &mut scratch);
        assert!(scratch.capacity() >= cap);
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = Rng::new(23);
        let p = r.permutation(64);
        let mut s = p.clone();
        s.sort_unstable();
        assert_eq!(s, (0..64).collect::<Vec<u32>>());
    }
}
