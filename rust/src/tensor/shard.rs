//! Gradient sharding: fixed-size contiguous chunks of a flat vector.
//!
//! The sharded compression/aggregation pipeline splits `v ∈ R^d` into
//! `⌈d / shard_size⌉` contiguous shards. Shard boundaries are a pure
//! function of `(d, shard_size)` — never of the thread count — which is
//! one half of the bit-reproducibility contract of the parallel paths
//! (the other half is the per-shard RNG stream derivation in
//! [`crate::tensor::Rng::shard_streams`]). See
//! [`crate::compress::ParCompressor`] and
//! `coordinator::Server::apply_round`.

use std::ops::Range;

/// Shard geometry for a length-`d` vector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardSpec {
    /// total vector length
    pub d: usize,
    /// elements per shard (the last shard may be shorter); always >= 1
    pub shard_size: usize,
}

impl ShardSpec {
    /// `shard_size` is clamped to `>= 1`; `d = 0` yields zero shards.
    pub fn new(d: usize, shard_size: usize) -> ShardSpec {
        ShardSpec { d, shard_size: shard_size.max(1) }
    }

    pub fn num_shards(&self) -> usize {
        self.d.div_ceil(self.shard_size)
    }

    /// Global index range `[start, end)` of shard `i`.
    pub fn range(&self, i: usize) -> Range<usize> {
        debug_assert!(i < self.num_shards());
        let start = i * self.shard_size;
        start..(start + self.shard_size).min(self.d)
    }

    /// Length of shard `i`.
    pub fn len(&self, i: usize) -> usize {
        let r = self.range(i);
        r.end - r.start
    }

    /// All shard ranges, in order.
    pub fn ranges(&self) -> impl Iterator<Item = Range<usize>> + '_ {
        (0..self.num_shards()).map(|i| self.range(i))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_division() {
        let s = ShardSpec::new(100, 25);
        assert_eq!(s.num_shards(), 4);
        assert_eq!(s.range(0), 0..25);
        assert_eq!(s.range(3), 75..100);
        assert_eq!(s.len(3), 25);
    }

    #[test]
    fn ragged_tail() {
        let s = ShardSpec::new(103, 25);
        assert_eq!(s.num_shards(), 5);
        assert_eq!(s.range(4), 100..103);
        assert_eq!(s.len(4), 3);
    }

    #[test]
    fn ranges_partition_exactly() {
        for (d, sz) in [(1usize, 1usize), (7, 3), (64, 64), (64, 65), (1000, 1)] {
            let s = ShardSpec::new(d, sz);
            let mut covered = 0;
            for (i, r) in s.ranges().enumerate() {
                assert_eq!(r.start, covered, "d={d} sz={sz} i={i}");
                covered = r.end;
            }
            assert_eq!(covered, d, "d={d} sz={sz}");
        }
    }

    #[test]
    fn zero_and_clamp_edges() {
        assert_eq!(ShardSpec::new(0, 8).num_shards(), 0);
        // shard_size 0 clamps to 1
        assert_eq!(ShardSpec::new(3, 0).num_shards(), 3);
        assert_eq!(ShardSpec::new(3, 0).range(2), 2..3);
    }
}
