//! Flat-vector math substrate.
//!
//! Everything in the coordinator operates on flat `f32` vectors (the L2
//! models expose a single flat parameter/gradient vector — see
//! `python/compile/model.py`), so this module is the numeric workhorse:
//! BLAS-1 style ops, norms, and magnitude-selection utilities.
//!
//! The op bodies live in [`kernels`]: a canonical fixed-lane-order
//! kernel layer whose scalar and (optional, `--features simd`) AVX2
//! paths are bit-identical by construction. Reductions here accumulate
//! in f64 across 8 fixed lanes — deterministic, but a *different*
//! (documented) association than a plain sequential sum.

pub mod kernels;
pub mod rng;
pub mod select;
pub mod shard;

pub use rng::Rng;
pub use shard::ShardSpec;

/// `y += alpha * x`
pub fn axpy(y: &mut [f32], alpha: f32, x: &[f32]) {
    kernels::axpy(y, alpha, x)
}

/// `y = alpha * x` (overwrites)
pub fn scaled_copy(y: &mut [f32], alpha: f32, x: &[f32]) {
    kernels::scaled_copy(y, alpha, x)
}

/// `x *= alpha`
pub fn scale(x: &mut [f32], alpha: f32) {
    kernels::scale(x, alpha)
}

/// Dot product (f64 accumulation for stability on long vectors).
pub fn dot(a: &[f32], b: &[f32]) -> f64 {
    kernels::dot(a, b)
}

/// Squared l2 norm, f64 accumulated.
pub fn sq_norm(x: &[f32]) -> f64 {
    kernels::sq_norm(x)
}

/// l2 norm.
pub fn norm(x: &[f32]) -> f64 {
    sq_norm(x).sqrt()
}

/// l1 norm.
pub fn l1_norm(x: &[f32]) -> f64 {
    kernels::l1_norm(x)
}

/// Largest magnitude entry (0.0 for an empty slice).
pub fn max_abs(x: &[f32]) -> f32 {
    kernels::max_abs(x)
}

/// Elementwise difference `a - b` into a fresh vector.
pub fn sub(a: &[f32], b: &[f32]) -> Vec<f32> {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x - y).collect()
}

/// Zero the buffer.
pub fn zero(x: &mut [f32]) {
    kernels::fill(x, 0.0)
}

/// Squared l2 distance between two vectors.
pub fn sq_dist(a: &[f32], b: &[f32]) -> f64 {
    kernels::sq_dist(a, b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axpy_accumulates() {
        let mut y = vec![1.0, 2.0, 3.0];
        axpy(&mut y, 2.0, &[10.0, 20.0, 30.0]);
        assert_eq!(y, vec![21.0, 42.0, 63.0]);
    }

    #[test]
    fn dot_and_norms() {
        let a = [3.0f32, 4.0];
        assert_eq!(dot(&a, &a), 25.0);
        assert_eq!(sq_norm(&a), 25.0);
        assert_eq!(norm(&a), 5.0);
        assert_eq!(l1_norm(&a), 7.0);
        assert_eq!(max_abs(&[-9.0, 2.0]), 9.0);
    }

    #[test]
    fn sq_dist_symmetric() {
        let a = [1.0f32, 2.0, -1.0];
        let b = [0.0f32, 4.0, 1.0];
        assert_eq!(sq_dist(&a, &b), sq_dist(&b, &a));
        assert_eq!(sq_dist(&a, &b), 1.0 + 4.0 + 4.0);
    }

    #[test]
    fn scaled_copy_and_scale() {
        let mut y = vec![0.0; 3];
        scaled_copy(&mut y, 0.5, &[2.0, 4.0, 6.0]);
        assert_eq!(y, vec![1.0, 2.0, 3.0]);
        scale(&mut y, 2.0);
        assert_eq!(y, vec![2.0, 4.0, 6.0]);
        zero(&mut y);
        assert_eq!(y, vec![0.0; 3]);
    }

    #[test]
    fn sub_elementwise() {
        assert_eq!(sub(&[3.0, 2.0], &[1.0, 5.0]), vec![2.0, -3.0]);
    }

    #[test]
    fn f64_accumulation_is_stable() {
        // 1M small values whose f32 running sum would drift
        let x = vec![1e-4f32; 1_000_000];
        let n = sq_norm(&x);
        assert!((n - 1e-8 * 1e6).abs() < 1e-9, "{n}");
    }
}
