//! Vectorized kernels with a **canonical fixed-lane accumulation order**.
//!
//! Every kernel here exists in two forms:
//!
//! * [`scalar`] — the reference implementation. Reductions process the
//!   input in 8-wide chunks ([`LANES`]) holding one accumulator per
//!   lane; tail elements past the last full chunk feed lane `i % 8`;
//!   the eight lane accumulators are combined with the fixed tree
//!   `((a0+a1)+(a2+a3)) + ((a4+a5)+(a6+a7))` ([`scalar::reduce8`]).
//!   The chunked shape is exactly what LLVM auto-vectorizes on any
//!   target, so the "scalar" fallback is already SIMD-speed in release
//!   builds without any feature flag or unsafe code.
//! * an AVX2 path (x86_64 only, behind the `simd` cargo feature,
//!   selected at runtime via `is_x86_feature_detected!`) that performs
//!   the **same lane-wise operations in the same order** — mul then add
//!   (never FMA, which would fuse the rounding step), the same clamp
//!   operand order, the same reduce tree. Scalar and SIMD results are
//!   therefore **bit-identical by construction** for finite inputs,
//!   which is what lets the bit-reproducibility property suites
//!   (`tests/prop_simd.rs`, `tests/prop_shard.rs`) gate the fast path.
//!
//! Elementwise kernels (axpy, scale, RTN/fixed-point/sign transforms)
//! have no cross-lane interaction, so their bit-identity needs no lane
//! discipline — only the "no FMA, same operation sequence" rule.
//!
//! NaN caveat: the AVX2 `max`/`signum` idioms differ from the scalar
//! ones in NaN payload/propagation. The gradient path only ever feeds
//! finite values (asserted upstream); the bit-identity contract is for
//! finite inputs.
//!
//! See README §"Hot path: vectorized kernels & the scratch arena" for
//! the design rationale and bench reproduction steps.

/// Chunk width of the canonical accumulation order (8 × f32 = one
/// 256-bit vector; reductions widen to f64 in two 4-lane halves).
pub const LANES: usize = 8;

/// True when the AVX2 fast path is compiled in (`--features simd` on
/// x86_64) *and* the CPU supports it. Detection result is cached.
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
pub fn simd_active() -> bool {
    static AVX2: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
    *AVX2.get_or_init(|| is_x86_feature_detected!("avx2"))
}

/// True when the AVX2 fast path is compiled in (`--features simd` on
/// x86_64) *and* the CPU supports it. Always false on this build.
#[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
pub fn simd_active() -> bool {
    false
}

/// Reference kernels in the canonical lane order. Public so the prop
/// tests (and benches) can pin the dispatched path against them.
// repolint: no_alloc(start) — the hot kernels work in caller-owned
// buffers only; an allocation here would break the steady-state
// zero-alloc round contract (tests/alloc_zero.rs is the dynamic twin).
pub mod scalar {
    use super::LANES;

    /// Fixed reduction tree over the 8 lane accumulators. Every
    /// reduction kernel — scalar or vector — must end through this
    /// exact association.
    #[inline]
    pub fn reduce8(a: [f64; LANES]) -> f64 {
        ((a[0] + a[1]) + (a[2] + a[3])) + ((a[4] + a[5]) + (a[6] + a[7]))
    }

    /// `y ← y + alpha·x` (mul then add; never fused).
    pub fn axpy(y: &mut [f32], alpha: f32, x: &[f32]) {
        debug_assert_eq!(y.len(), x.len());
        let mut yc = y.chunks_exact_mut(LANES);
        let mut xc = x.chunks_exact(LANES);
        for (yy, xx) in yc.by_ref().zip(xc.by_ref()) {
            for j in 0..LANES {
                yy[j] += alpha * xx[j];
            }
        }
        for (yi, xi) in yc.into_remainder().iter_mut().zip(xc.remainder()) {
            *yi += alpha * xi;
        }
    }

    /// `y ← alpha·x`.
    pub fn scaled_copy(y: &mut [f32], alpha: f32, x: &[f32]) {
        debug_assert_eq!(y.len(), x.len());
        let mut yc = y.chunks_exact_mut(LANES);
        let mut xc = x.chunks_exact(LANES);
        for (yy, xx) in yc.by_ref().zip(xc.by_ref()) {
            for j in 0..LANES {
                yy[j] = alpha * xx[j];
            }
        }
        for (yi, xi) in yc.into_remainder().iter_mut().zip(xc.remainder()) {
            *yi = alpha * xi;
        }
    }

    /// `x ← alpha·x`.
    pub fn scale(x: &mut [f32], alpha: f32) {
        let mut xc = x.chunks_exact_mut(LANES);
        for xx in xc.by_ref() {
            for j in 0..LANES {
                xx[j] *= alpha;
            }
        }
        for xi in xc.into_remainder() {
            *xi *= alpha;
        }
    }

    /// `Σ x_i²` in f64, canonical lane order.
    pub fn sq_norm(x: &[f32]) -> f64 {
        let mut acc = [0.0f64; LANES];
        let mut xc = x.chunks_exact(LANES);
        for xx in xc.by_ref() {
            for j in 0..LANES {
                let v = xx[j] as f64;
                acc[j] += v * v;
            }
        }
        for (j, xi) in xc.remainder().iter().enumerate() {
            let v = *xi as f64;
            acc[j] += v * v;
        }
        reduce8(acc)
    }

    /// `Σ x_i·y_i` in f64, canonical lane order.
    pub fn dot(x: &[f32], y: &[f32]) -> f64 {
        debug_assert_eq!(x.len(), y.len());
        let mut acc = [0.0f64; LANES];
        let mut xc = x.chunks_exact(LANES);
        let mut yc = y.chunks_exact(LANES);
        for (xx, yy) in xc.by_ref().zip(yc.by_ref()) {
            for j in 0..LANES {
                acc[j] += xx[j] as f64 * yy[j] as f64;
            }
        }
        for (j, (xi, yi)) in xc.remainder().iter().zip(yc.remainder()).enumerate() {
            acc[j] += *xi as f64 * *yi as f64;
        }
        reduce8(acc)
    }

    /// `Σ |x_i|` in f64, canonical lane order.
    pub fn l1_norm(x: &[f32]) -> f64 {
        let mut acc = [0.0f64; LANES];
        let mut xc = x.chunks_exact(LANES);
        for xx in xc.by_ref() {
            for j in 0..LANES {
                acc[j] += xx[j].abs() as f64;
            }
        }
        for (j, xi) in xc.remainder().iter().enumerate() {
            acc[j] += xi.abs() as f64;
        }
        reduce8(acc)
    }

    /// `Σ (x_i − y_i)²` in f64, canonical lane order.
    pub fn sq_dist(x: &[f32], y: &[f32]) -> f64 {
        debug_assert_eq!(x.len(), y.len());
        let mut acc = [0.0f64; LANES];
        let mut xc = x.chunks_exact(LANES);
        let mut yc = y.chunks_exact(LANES);
        for (xx, yy) in xc.by_ref().zip(yc.by_ref()) {
            for j in 0..LANES {
                let dj = (xx[j] - yy[j]) as f64;
                acc[j] += dj * dj;
            }
        }
        for (j, (xi, yi)) in xc.remainder().iter().zip(yc.remainder()).enumerate() {
            let dj = (*xi - *yi) as f64;
            acc[j] += dj * dj;
        }
        reduce8(acc)
    }

    /// `max_i |x_i|` (0 on empty), canonical lane order.
    pub fn max_abs(x: &[f32]) -> f32 {
        let mut m = [0.0f32; LANES];
        let mut xc = x.chunks_exact(LANES);
        for xx in xc.by_ref() {
            for j in 0..LANES {
                m[j] = m[j].max(xx[j].abs());
            }
        }
        for (j, xi) in xc.remainder().iter().enumerate() {
            m[j] = m[j].max(xi.abs());
        }
        (m[0].max(m[1])).max(m[2].max(m[3])).max((m[4].max(m[5])).max(m[6].max(m[7])))
    }

    /// RTN grid projection: `out_i = delta·clamp(round_ties_even(x_i/delta), ±c_units)`.
    pub fn rtn_apply(out: &mut [f32], v: &[f32], delta: f32, c_units: f32) {
        debug_assert_eq!(out.len(), v.len());
        for (o, x) in out.iter_mut().zip(v) {
            *o = delta * (x / delta).round_ties_even().clamp(-c_units, c_units);
        }
    }

    /// Fixed-point truncation toward zero on the normalized value:
    /// `e = x/scale; out = (signum(e)·⌊|e|·2^f⌋)/2^f · scale` with
    /// `pow2 = 2^f`. `scale` must be nonzero (callers early-out).
    pub fn fx_apply(out: &mut [f32], v: &[f32], pow2: f32, scale: f32) {
        debug_assert_eq!(out.len(), v.len());
        for (o, x) in out.iter_mut().zip(v) {
            let e = x / scale;
            *o = e.signum() * (e.abs() * pow2).floor() / pow2 * scale;
        }
    }

    /// Mantissa truncation: `out_i = from_bits(to_bits(x_i) & mask)`.
    pub fn fp_truncate(out: &mut [f32], v: &[f32], mask: u32) {
        debug_assert_eq!(out.len(), v.len());
        for (o, x) in out.iter_mut().zip(v) {
            *o = f32::from_bits(x.to_bits() & mask);
        }
    }

    /// Sign packing: `out_i = ±mag` by the sign test `x_i >= 0`.
    pub fn sign_fill(out: &mut [f32], v: &[f32], mag: f32) {
        debug_assert_eq!(out.len(), v.len());
        for (o, x) in out.iter_mut().zip(v) {
            *o = if *x >= 0.0 { mag } else { -mag };
        }
    }
}
// repolint: no_alloc(end)

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod avx2 {
    //! AVX2 twins of the [`super::scalar`] kernels. Same operation
    //! sequence lane-by-lane (no FMA, same clamp operand order, same
    //! reduce tree) ⇒ bit-identical for finite inputs. Every fn is
    //! `unsafe` only for the `target_feature` contract: callers must
    //! have verified AVX2 support ([`super::simd_active`]).
    use super::LANES;
    use core::arch::x86_64::*;

    /// # Safety: requires AVX2 (checked by `simd_active`).
    #[target_feature(enable = "avx2")]
    pub unsafe fn axpy(y: &mut [f32], alpha: f32, x: &[f32]) {
        debug_assert_eq!(y.len(), x.len());
        let chunks = y.len() / LANES;
        let a = _mm256_set1_ps(alpha);
        for c in 0..chunks {
            let p = y.as_mut_ptr().add(c * LANES);
            let yy = _mm256_loadu_ps(p);
            let xx = _mm256_loadu_ps(x.as_ptr().add(c * LANES));
            // mul then add — never fused, matching the scalar kernel
            _mm256_storeu_ps(p, _mm256_add_ps(yy, _mm256_mul_ps(a, xx)));
        }
        for i in chunks * LANES..y.len() {
            *y.get_unchecked_mut(i) += alpha * *x.get_unchecked(i);
        }
    }

    /// # Safety: requires AVX2 (checked by `simd_active`).
    #[target_feature(enable = "avx2")]
    pub unsafe fn scaled_copy(y: &mut [f32], alpha: f32, x: &[f32]) {
        debug_assert_eq!(y.len(), x.len());
        let chunks = y.len() / LANES;
        let a = _mm256_set1_ps(alpha);
        for c in 0..chunks {
            let xx = _mm256_loadu_ps(x.as_ptr().add(c * LANES));
            _mm256_storeu_ps(y.as_mut_ptr().add(c * LANES), _mm256_mul_ps(a, xx));
        }
        for i in chunks * LANES..y.len() {
            *y.get_unchecked_mut(i) = alpha * *x.get_unchecked(i);
        }
    }

    /// # Safety: requires AVX2 (checked by `simd_active`).
    #[target_feature(enable = "avx2")]
    pub unsafe fn scale(x: &mut [f32], alpha: f32) {
        let chunks = x.len() / LANES;
        let a = _mm256_set1_ps(alpha);
        for c in 0..chunks {
            let p = x.as_mut_ptr().add(c * LANES);
            _mm256_storeu_ps(p, _mm256_mul_ps(_mm256_loadu_ps(p), a));
        }
        for i in chunks * LANES..x.len() {
            *x.get_unchecked_mut(i) *= alpha;
        }
    }

    /// # Safety: requires AVX2 (checked by `simd_active`).
    #[target_feature(enable = "avx2")]
    pub unsafe fn sq_norm(x: &[f32]) -> f64 {
        let chunks = x.len() / LANES;
        // lanes 0..4 and 4..8 of the canonical accumulator array
        let mut acc_lo = _mm256_setzero_pd();
        let mut acc_hi = _mm256_setzero_pd();
        for c in 0..chunks {
            let v = _mm256_loadu_ps(x.as_ptr().add(c * LANES));
            let lo = _mm256_cvtps_pd(_mm256_castps256_ps128(v));
            let hi = _mm256_cvtps_pd(_mm256_extractf128_ps::<1>(v));
            acc_lo = _mm256_add_pd(acc_lo, _mm256_mul_pd(lo, lo));
            acc_hi = _mm256_add_pd(acc_hi, _mm256_mul_pd(hi, hi));
        }
        let mut a = [0.0f64; LANES];
        _mm256_storeu_pd(a.as_mut_ptr(), acc_lo);
        _mm256_storeu_pd(a.as_mut_ptr().add(4), acc_hi);
        for (j, xi) in x[chunks * LANES..].iter().enumerate() {
            let v = *xi as f64;
            a[j] += v * v;
        }
        super::scalar::reduce8(a)
    }

    /// # Safety: requires AVX2 (checked by `simd_active`).
    #[target_feature(enable = "avx2")]
    pub unsafe fn max_abs(x: &[f32]) -> f32 {
        let chunks = x.len() / LANES;
        let sign_mask = _mm256_set1_ps(-0.0);
        let mut acc = _mm256_setzero_ps();
        for c in 0..chunks {
            let v = _mm256_loadu_ps(x.as_ptr().add(c * LANES));
            acc = _mm256_max_ps(acc, _mm256_andnot_ps(sign_mask, v));
        }
        let mut m = [0.0f32; LANES];
        _mm256_storeu_ps(m.as_mut_ptr(), acc);
        for (j, xi) in x[chunks * LANES..].iter().enumerate() {
            m[j] = m[j].max(xi.abs());
        }
        (m[0].max(m[1])).max(m[2].max(m[3])).max((m[4].max(m[5])).max(m[6].max(m[7])))
    }

    /// # Safety: requires AVX2 (checked by `simd_active`).
    #[target_feature(enable = "avx2")]
    pub unsafe fn rtn_apply(out: &mut [f32], v: &[f32], delta: f32, c_units: f32) {
        debug_assert_eq!(out.len(), v.len());
        let chunks = v.len() / LANES;
        let d = _mm256_set1_ps(delta);
        let lo = _mm256_set1_ps(-c_units);
        let hi = _mm256_set1_ps(c_units);
        for c in 0..chunks {
            let x = _mm256_loadu_ps(v.as_ptr().add(c * LANES));
            let t = _mm256_div_ps(x, d);
            // nearest-even, like round_ties_even
            let r = _mm256_round_ps::<{ _MM_FROUND_TO_NEAREST_INT | _MM_FROUND_NO_EXC }>(t);
            // clamp = max(·, lo) then min(·, hi): f32::clamp's order
            let cl = _mm256_min_ps(_mm256_max_ps(r, lo), hi);
            _mm256_storeu_ps(out.as_mut_ptr().add(c * LANES), _mm256_mul_ps(d, cl));
        }
        for i in chunks * LANES..v.len() {
            let x = *v.get_unchecked(i);
            *out.get_unchecked_mut(i) =
                delta * (x / delta).round_ties_even().clamp(-c_units, c_units);
        }
    }

    /// # Safety: requires AVX2 (checked by `simd_active`).
    #[target_feature(enable = "avx2")]
    pub unsafe fn fx_apply(out: &mut [f32], v: &[f32], pow2: f32, scale: f32) {
        debug_assert_eq!(out.len(), v.len());
        let chunks = v.len() / LANES;
        let p2 = _mm256_set1_ps(pow2);
        let sc = _mm256_set1_ps(scale);
        let sign_mask = _mm256_set1_ps(-0.0);
        for c in 0..chunks {
            let x = _mm256_loadu_ps(v.as_ptr().add(c * LANES));
            let e = _mm256_div_ps(x, sc);
            let sign = _mm256_and_ps(e, sign_mask);
            let mag = _mm256_andnot_ps(sign_mask, e);
            let f = _mm256_floor_ps(_mm256_mul_ps(mag, p2));
            // signum(e)·f ≡ f with e's sign bit copied on (f ≥ +0)
            let sf = _mm256_or_ps(f, sign);
            let r = _mm256_mul_ps(_mm256_div_ps(sf, p2), sc);
            _mm256_storeu_ps(out.as_mut_ptr().add(c * LANES), r);
        }
        for i in chunks * LANES..v.len() {
            let e = *v.get_unchecked(i) / scale;
            *out.get_unchecked_mut(i) = e.signum() * (e.abs() * pow2).floor() / pow2 * scale;
        }
    }

    /// # Safety: requires AVX2 (checked by `simd_active`).
    #[target_feature(enable = "avx2")]
    pub unsafe fn fp_truncate(out: &mut [f32], v: &[f32], mask: u32) {
        debug_assert_eq!(out.len(), v.len());
        let chunks = v.len() / LANES;
        let m = _mm256_set1_epi32(mask as i32);
        for c in 0..chunks {
            let x = _mm256_loadu_si256(v.as_ptr().add(c * LANES) as *const __m256i);
            _mm256_storeu_si256(
                out.as_mut_ptr().add(c * LANES) as *mut __m256i,
                _mm256_and_si256(x, m),
            );
        }
        for i in chunks * LANES..v.len() {
            *out.get_unchecked_mut(i) = f32::from_bits(v.get_unchecked(i).to_bits() & mask);
        }
    }

    /// # Safety: requires AVX2 (checked by `simd_active`).
    #[target_feature(enable = "avx2")]
    pub unsafe fn sign_fill(out: &mut [f32], v: &[f32], mag: f32) {
        debug_assert_eq!(out.len(), v.len());
        let chunks = v.len() / LANES;
        let pos = _mm256_set1_ps(mag);
        let neg = _mm256_set1_ps(-mag);
        let zero = _mm256_setzero_ps();
        for c in 0..chunks {
            let x = _mm256_loadu_ps(v.as_ptr().add(c * LANES));
            // ordered quiet GE, like the scalar `x >= 0.0`
            let ge = _mm256_cmp_ps::<{ _CMP_GE_OQ }>(x, zero);
            _mm256_storeu_ps(out.as_mut_ptr().add(c * LANES), _mm256_blendv_ps(neg, pos, ge));
        }
        for i in chunks * LANES..v.len() {
            *out.get_unchecked_mut(i) = if *v.get_unchecked(i) >= 0.0 { mag } else { -mag };
        }
    }
}

// ---- runtime-dispatched entry points ----------------------------------
//
// Each wrapper takes the AVX2 path iff `simd_active()`; otherwise the
// canonical scalar kernel runs. Kernels with no intrinsic twin (dot,
// l1_norm, sq_dist, gathers, scatter, key packing) always run the
// canonical loop — it auto-vectorizes — and keep a wrapper here so call
// sites are uniform.

/// `y ← y + alpha·x` (dispatched).
pub fn axpy(y: &mut [f32], alpha: f32, x: &[f32]) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if simd_active() {
        // SAFETY: AVX2 presence checked by simd_active()
        unsafe { avx2::axpy(y, alpha, x) };
        return;
    }
    scalar::axpy(y, alpha, x)
}

/// `y ← alpha·x` (dispatched).
pub fn scaled_copy(y: &mut [f32], alpha: f32, x: &[f32]) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if simd_active() {
        // SAFETY: AVX2 presence checked by simd_active()
        unsafe { avx2::scaled_copy(y, alpha, x) };
        return;
    }
    scalar::scaled_copy(y, alpha, x)
}

/// `x ← alpha·x` (dispatched).
pub fn scale(x: &mut [f32], alpha: f32) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if simd_active() {
        // SAFETY: AVX2 presence checked by simd_active()
        unsafe { avx2::scale(x, alpha) };
        return;
    }
    scalar::scale(x, alpha)
}

/// `Σ x_i²` (dispatched).
pub fn sq_norm(x: &[f32]) -> f64 {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if simd_active() {
        // SAFETY: AVX2 presence checked by simd_active()
        return unsafe { avx2::sq_norm(x) };
    }
    scalar::sq_norm(x)
}

/// `Σ x_i·y_i` (canonical loop; auto-vectorized).
pub fn dot(x: &[f32], y: &[f32]) -> f64 {
    scalar::dot(x, y)
}

/// `Σ |x_i|` (canonical loop; auto-vectorized).
pub fn l1_norm(x: &[f32]) -> f64 {
    scalar::l1_norm(x)
}

/// `Σ (x_i − y_i)²` (canonical loop; auto-vectorized).
pub fn sq_dist(x: &[f32], y: &[f32]) -> f64 {
    scalar::sq_dist(x, y)
}

/// `max_i |x_i|` (dispatched).
pub fn max_abs(x: &[f32]) -> f32 {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if simd_active() {
        // SAFETY: AVX2 presence checked by simd_active()
        return unsafe { avx2::max_abs(x) };
    }
    scalar::max_abs(x)
}

/// `x ← c` elementwise (order-independent; delegates to `slice::fill`).
pub fn fill(x: &mut [f32], c: f32) {
    x.fill(c)
}

/// RTN grid projection (dispatched). See [`scalar::rtn_apply`].
pub fn rtn_apply(out: &mut [f32], v: &[f32], delta: f32, c_units: f32) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if simd_active() {
        // SAFETY: AVX2 presence checked by simd_active()
        unsafe { avx2::rtn_apply(out, v, delta, c_units) };
        return;
    }
    scalar::rtn_apply(out, v, delta, c_units)
}

/// Fixed-point truncation (dispatched). See [`scalar::fx_apply`].
pub fn fx_apply(out: &mut [f32], v: &[f32], pow2: f32, scale: f32) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if simd_active() {
        // SAFETY: AVX2 presence checked by simd_active()
        unsafe { avx2::fx_apply(out, v, pow2, scale) };
        return;
    }
    scalar::fx_apply(out, v, pow2, scale)
}

/// Mantissa truncation (dispatched). See [`scalar::fp_truncate`].
pub fn fp_truncate(out: &mut [f32], v: &[f32], mask: u32) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if simd_active() {
        // SAFETY: AVX2 presence checked by simd_active()
        unsafe { avx2::fp_truncate(out, v, mask) };
        return;
    }
    scalar::fp_truncate(out, v, mask)
}

/// Sign packing (dispatched). See [`scalar::sign_fill`].
pub fn sign_fill(out: &mut [f32], v: &[f32], mag: f32) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if simd_active() {
        // SAFETY: AVX2 presence checked by simd_active()
        unsafe { avx2::sign_fill(out, v, mag) };
        return;
    }
    scalar::sign_fill(out, v, mag)
}

/// Sparse gather: `out ← v[idx]` (clears `out` first).
pub fn gather(v: &[f32], idx: &[u32], out: &mut Vec<f32>) {
    out.clear();
    out.reserve(idx.len());
    for &i in idx {
        out.push(v[i as usize]);
    }
}

/// Sparse gather of magnitudes: `out ← |v[idx]|` (clears `out` first).
pub fn gather_abs(v: &[f32], idx: &[u32], out: &mut Vec<f32>) {
    out.clear();
    out.reserve(idx.len());
    for &i in idx {
        out.push(v[i as usize].abs());
    }
}

/// Sparse gather with scaling: `out ← scale·v[idx]` (clears `out` first).
pub fn gather_scaled(v: &[f32], idx: &[u32], scale: f32, out: &mut Vec<f32>) {
    out.clear();
    out.reserve(idx.len());
    for &i in idx {
        out.push(v[i as usize] * scale);
    }
}

/// Sparse scatter-accumulate: `acc[idx_j] += scale·val_j`.
pub fn scatter_add(acc: &mut [f32], idx: &[u32], val: &[f32], scale: f32) {
    debug_assert_eq!(idx.len(), val.len());
    for (i, x) in idx.iter().zip(val) {
        acc[*i as usize] += scale * x;
    }
}

/// Pack `v` into magnitude-descending sort keys: ascending u64 order of
/// `(!(|v_i| bits) << 32) | i` is descending `|v_i|` with ascending
/// index as the deterministic tie-break — a **strict** total order, so
/// any correct partial/full sort of these keys agrees on every prefix.
/// Clears `out` first.
pub fn pack_desc_keys(v: &[f32], out: &mut Vec<u64>) {
    out.clear();
    out.reserve(v.len());
    for (i, x) in v.iter().enumerate() {
        let mag = (x.abs().to_bits() as u64) << 32;
        out.push((!mag & 0xFFFF_FFFF_0000_0000) | i as u64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    fn test_vec(d: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..d).map(|_| rng.normal() as f32 * 3.0).collect()
    }

    const SIZES: [usize; 8] = [0, 1, 7, 8, 9, 63, 64, 1000];

    #[test]
    fn dispatch_matches_scalar_reductions() {
        for (s, d) in SIZES.iter().enumerate() {
            let x = test_vec(*d, s as u64 + 1);
            let y = test_vec(*d, s as u64 + 100);
            assert_eq!(sq_norm(&x).to_bits(), scalar::sq_norm(&x).to_bits(), "d={d}");
            assert_eq!(max_abs(&x).to_bits(), scalar::max_abs(&x).to_bits(), "d={d}");
            assert_eq!(dot(&x, &y).to_bits(), scalar::dot(&x, &y).to_bits(), "d={d}");
            assert_eq!(l1_norm(&x).to_bits(), scalar::l1_norm(&x).to_bits(), "d={d}");
            assert_eq!(sq_dist(&x, &y).to_bits(), scalar::sq_dist(&x, &y).to_bits(), "d={d}");
        }
    }

    #[test]
    fn dispatch_matches_scalar_elementwise() {
        for (s, d) in SIZES.iter().enumerate() {
            let x = test_vec(*d, s as u64 + 7);
            let mut a = test_vec(*d, s as u64 + 70);
            let mut b = a.clone();
            axpy(&mut a, 0.37, &x);
            scalar::axpy(&mut b, 0.37, &x);
            assert_eq!(bits(&a), bits(&b), "axpy d={d}");
            scaled_copy(&mut a, -1.6, &x);
            scalar::scaled_copy(&mut b, -1.6, &x);
            assert_eq!(bits(&a), bits(&b), "scaled_copy d={d}");
            scale(&mut a, 0.11);
            scalar::scale(&mut b, 0.11);
            assert_eq!(bits(&a), bits(&b), "scale d={d}");
            rtn_apply(&mut a, &x, 0.25, 3.0);
            scalar::rtn_apply(&mut b, &x, 0.25, 3.0);
            assert_eq!(bits(&a), bits(&b), "rtn d={d}");
            fx_apply(&mut a, &x, 16.0, 2.5);
            scalar::fx_apply(&mut b, &x, 16.0, 2.5);
            assert_eq!(bits(&a), bits(&b), "fx d={d}");
            fp_truncate(&mut a, &x, !((1u32 << 19) - 1));
            scalar::fp_truncate(&mut b, &x, !((1u32 << 19) - 1));
            assert_eq!(bits(&a), bits(&b), "fp d={d}");
            sign_fill(&mut a, &x, 0.83);
            scalar::sign_fill(&mut b, &x, 0.83);
            assert_eq!(bits(&a), bits(&b), "sign d={d}");
        }
    }

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn reductions_match_naive_within_tolerance() {
        let x = test_vec(1000, 5);
        let y = test_vec(1000, 6);
        let naive_sq: f64 = x.iter().map(|v| *v as f64 * *v as f64).sum();
        assert!((sq_norm(&x) - naive_sq).abs() < 1e-9 * naive_sq.max(1.0));
        let naive_dot: f64 = x.iter().zip(&y).map(|(a, b)| *a as f64 * *b as f64).sum();
        assert!((dot(&x, &y) - naive_dot).abs() < 1e-9 * naive_dot.abs().max(1.0));
        let naive_max = x.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        assert_eq!(max_abs(&x), naive_max);
    }

    #[test]
    fn elementwise_semantics() {
        let v = [1.3f32, -0.7, 0.0, 2.49, -2.51];
        let mut out = [0.0f32; 5];
        rtn_apply(&mut out, &v, 1.0, 2.0);
        assert_eq!(out, [1.0, -1.0, 0.0, 2.0, -2.0]);
        sign_fill(&mut out, &v, 2.0);
        assert_eq!(out, [2.0, -2.0, 2.0, 2.0, -2.0]);
        fx_apply(&mut out, &v, 4.0, 1.0); // truncate toward zero at 1/4 steps
        assert_eq!(out, [1.25, -0.5, 0.0, 2.25, -2.5]);
    }

    #[test]
    fn gather_scatter_roundtrip() {
        let v = test_vec(64, 9);
        let idx = [3u32, 0, 63, 17];
        let mut g = Vec::new();
        gather(&v, &idx, &mut g);
        assert_eq!(g, vec![v[3], v[0], v[63], v[17]]);
        let mut acc = vec![0.0f32; 64];
        scatter_add(&mut acc, &idx, &g, 2.0);
        assert_eq!(acc[3], 2.0 * v[3]);
        assert_eq!(acc[1], 0.0);
        let mut ga = Vec::new();
        gather_abs(&v, &idx, &mut ga);
        assert_eq!(ga[0], v[3].abs());
        let mut gs = Vec::new();
        gather_scaled(&v, &idx, -1.0, &mut gs);
        assert_eq!(gs[1], -v[0]);
    }

    #[test]
    fn desc_keys_are_strict_total_order() {
        let v = [1.0f32, -5.0, 3.0, -5.0, 0.0];
        let mut keys = Vec::new();
        pack_desc_keys(&v, &mut keys);
        let mut sorted = keys.clone();
        sorted.sort_unstable();
        // desc magnitude, ties broken by ascending index
        let order: Vec<u32> = sorted.iter().map(|k| *k as u32).collect();
        assert_eq!(order, vec![1, 3, 2, 0, 4]);
        // strictness: no two keys equal
        sorted.dedup();
        assert_eq!(sorted.len(), v.len());
    }

    #[test]
    fn lane_order_is_the_documented_tree() {
        // 8 values whose pairwise sums are exact: the tree must
        // reproduce the documented association exactly
        let a = [1.0f64, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0];
        assert_eq!(scalar::reduce8(a), 255.0);
        // tail elements feed lane i % 8: 9th element lands in lane 0
        let x = [1.0f32; 9];
        assert_eq!(scalar::sq_norm(&x), 9.0);
    }
}
