//! Magnitude selection utilities: top-k, argsort-by-|v|, and segment views.
//!
//! Top-k uses `select_nth_unstable` (introselect, O(d) expected) over
//! packed integer keys rather than a full sort — on the hot path this is
//! the difference between the compressor being free vs. dominating the
//! round (see README §"Hot path: vectorized kernels & the scratch
//! arena" for measurements and reproduction). A full descending argsort
//! is still provided for the adaptive s-Top-k path when the L1
//! `segstats` artifact is not in play.
//!
//! Every selection routine here runs over the keys packed by
//! [`crate::tensor::kernels::pack_desc_keys`]: ascending u64 order is
//! descending |v| with ascending index as tie-break — a **strict** total
//! order, so partial sorts (`select_nth_unstable` + prefix sort) agree
//! bit-for-bit with the full sort on every prefix. The `*_into`
//! variants take caller-owned buffers so the arena-backed compression
//! path stays allocation-free in steady state.

use super::kernels;

/// Size below which the comparison sort beats radix (histogram passes
/// don't amortize on small inputs).
const RADIX_MIN: usize = 1 << 14;

/// Indices of the k largest-|v| entries, |v|-descending, ties broken by
/// ascending index — fully deterministic. (`k >= d` returns `0..d` in
/// index order.)
pub fn top_k_indices(v: &[f32], k: usize) -> Vec<u32> {
    let mut keys = Vec::new();
    let mut out = Vec::new();
    top_k_indices_into(v, k, &mut keys, &mut out);
    out
}

/// [`top_k_indices`] into caller-owned buffers (`keys` is scratch; both
/// are cleared first). Identical result.
pub fn top_k_indices_into(v: &[f32], k: usize, keys: &mut Vec<u64>, out: &mut Vec<u32>) {
    let d = v.len();
    out.clear();
    if k == 0 {
        return;
    }
    if k >= d {
        out.extend(0..d as u32);
        return;
    }
    kernels::pack_desc_keys(v, keys);
    // nth position in ascending key order == descending |v| order
    keys.select_nth_unstable(k - 1);
    keys[..k].sort_unstable();
    out.extend(keys[..k].iter().map(|key| *key as u32));
}

/// Full argsort by |v| descending (ties: ascending index).
///
/// Packs `(|v| bits, index)` into one u64 per element and sorts those —
/// comparisons become single integer compares on contiguous memory
/// instead of two indirect f32 loads, which is ~3-4x faster at d = 1M
/// (README §"Hot path"). |v| is non-negative, so its IEEE-754 bit
/// pattern orders identically to its value; NaNs map above everything
/// and are tolerated (they sort first, deterministically).
pub fn argsort_desc_abs(v: &[f32]) -> Vec<u32> {
    let mut keys = Vec::new();
    let mut radix_buf = Vec::new();
    let mut out = Vec::new();
    argsort_desc_abs_into(v, &mut keys, &mut radix_buf, &mut out);
    out
}

/// [`argsort_desc_abs`] into caller-owned buffers (`keys` and
/// `radix_buf` are scratch; all are cleared first). Identical result.
pub fn argsort_desc_abs_into(
    v: &[f32],
    keys: &mut Vec<u64>,
    radix_buf: &mut Vec<u64>,
    out: &mut Vec<u32>,
) {
    kernels::pack_desc_keys(v, keys);
    // LSD radix over the 32 key bits (4 x 8-bit passes): O(d), ~2x over
    // comparison sort at d = 1M.
    if keys.len() >= RADIX_MIN {
        radix_sort_by_high32(keys, radix_buf);
    } else {
        keys.sort_unstable();
    }
    out.clear();
    out.reserve(keys.len());
    out.extend(keys.iter().map(|k| *k as u32));
}

/// The first `take` entries of [`argsort_desc_abs`] without paying for
/// the full sort when `take ≪ d`: partition at `take`, then sort only
/// the prefix. The packed keys form a strict total order, so this is
/// **exactly** the full sort's prefix — same indices, same order — for
/// every input (prop-tested in `tests/prop_simd.rs`).
pub fn argsort_prefix_desc_abs_into(
    v: &[f32],
    take: usize,
    keys: &mut Vec<u64>,
    radix_buf: &mut Vec<u64>,
    out: &mut Vec<u32>,
) {
    let d = v.len();
    let take = take.min(d);
    out.clear();
    if take == 0 {
        return;
    }
    if take == d {
        argsort_desc_abs_into(v, keys, radix_buf, out);
        return;
    }
    kernels::pack_desc_keys(v, keys);
    keys.select_nth_unstable(take - 1);
    keys[..take].sort_unstable();
    out.extend(keys[..take].iter().map(|key| *key as u32));
}

/// Stable LSD radix sort of packed `(key << 32) | idx` entries by the
/// high 32 bits, using a caller-owned scratch buffer. The low 32 bits
/// (indices) ride along, preserving the deterministic tie order from
/// the packing.
///
/// All four pass histograms are built in one read over the input
/// (halving memory traffic vs. a per-pass counting read), and passes
/// whose byte is constant across every key are skipped — a stable
/// no-op, so the result is bit-identical to the plain 4-pass sort.
fn radix_sort_by_high32(keys: &mut Vec<u64>, buf: &mut Vec<u64>) {
    let n = keys.len();
    buf.clear();
    buf.resize(n, 0);
    let mut hist = [[0usize; 256]; 4];
    for k in keys.iter() {
        let h = (k >> 32) as u32;
        hist[0][(h & 0xFF) as usize] += 1;
        hist[1][((h >> 8) & 0xFF) as usize] += 1;
        hist[2][((h >> 16) & 0xFF) as usize] += 1;
        hist[3][(h >> 24) as usize] += 1;
    }
    let mut flips = 0usize;
    {
        let mut src: &mut Vec<u64> = keys;
        let mut dst: &mut Vec<u64> = buf;
        for (pass, h) in hist.iter().enumerate() {
            // a pass whose byte is constant over every key is a stable no-op
            if h.iter().any(|&c| c == n) {
                continue;
            }
            let shift = 32 + pass as u32 * 8;
            let mut offsets = [0usize; 256];
            let mut acc = 0usize;
            for (o, c) in offsets.iter_mut().zip(h) {
                *o = acc;
                acc += c;
            }
            for k in src.iter() {
                let b = ((k >> shift) & 0xFF) as usize;
                dst[offsets[b]] = *k;
                offsets[b] += 1;
            }
            std::mem::swap(&mut src, &mut dst);
            flips += 1;
        }
    }
    if flips % 2 == 1 {
        // an odd number of executed passes left the result in `buf`
        std::mem::swap(keys, buf);
    }
}

/// Segment bounds for segment `l` (1-based, paper notation) of a length-d
/// vector split into ceil(d/s) segments of size s (last may be short).
pub fn segment_bounds(d: usize, s: usize, l: usize) -> (usize, usize) {
    debug_assert!(l >= 1);
    let lo = (l - 1) * s;
    let hi = (lo + s).min(d);
    (lo.min(d), hi)
}

/// Number of segments L = ceil(d/s).
pub fn num_segments(d: usize, s: usize) -> usize {
    d.div_ceil(s)
}

/// Squared norms of every segment of `sorted_vals` (already ordered by
/// |v| descending): `out[l-1] = (Delta^l)^2` of Lemma 3.4. This is the
/// rust-native fallback for the L1 `seg_energy` Pallas kernel.
pub fn segment_sq_norms(sorted_vals: &[f32], s: usize) -> Vec<f32> {
    let mut out = Vec::new();
    segment_sq_norms_into(sorted_vals, s, &mut out);
    out
}

/// [`segment_sq_norms`] into a caller-owned buffer (cleared first).
/// Each segment reduces through the canonical lane-order kernel.
pub fn segment_sq_norms_into(sorted_vals: &[f32], s: usize, out: &mut Vec<f32>) {
    let d = sorted_vals.len();
    let nl = num_segments(d, s);
    out.clear();
    out.reserve(nl);
    for l in 1..=nl {
        let (lo, hi) = segment_bounds(d, s, l);
        out.push(kernels::sq_norm(&sorted_vals[lo..hi]) as f32);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn top_k_basic() {
        let v = [1.0f32, -5.0, 3.0, 0.5, -2.0];
        let mut k2 = top_k_indices(&v, 2);
        k2.sort_unstable();
        assert_eq!(k2, vec![1, 2]); // |-5|, |3|
    }

    #[test]
    fn top_k_edges() {
        let v = [1.0f32, 2.0, 3.0];
        assert!(top_k_indices(&v, 0).is_empty());
        assert_eq!(top_k_indices(&v, 3).len(), 3);
        assert_eq!(top_k_indices(&v, 10).len(), 3);
        assert!(top_k_indices(&[], 0).is_empty());
    }

    #[test]
    fn top_k_is_argsort_prefix() {
        // the strict key order makes top-k exactly the argsort prefix
        let mut rng = Rng::new(1);
        for _ in 0..20 {
            let d = 1 + rng.below(500);
            let k = rng.below(d);
            let v: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
            assert_eq!(top_k_indices(&v, k), argsort_desc_abs(&v)[..k].to_vec());
        }
    }

    #[test]
    fn prefix_argsort_matches_full_sort_prefix() {
        let mut rng = Rng::new(4);
        for round in 0..20 {
            // cross the radix threshold on some rounds, and include
            // heavy ties (quantized values) to stress the tie order
            let d = if round % 3 == 0 { RADIX_MIN + rng.below(2000) } else { 1 + rng.below(3000) };
            let v: Vec<f32> = (0..d)
                .map(|_| {
                    let x = rng.normal() as f32;
                    if round % 2 == 0 { (x * 4.0).round() / 4.0 } else { x }
                })
                .collect();
            let full = argsort_desc_abs(&v);
            for take in [0usize, 1, 7, d / 2, d.saturating_sub(1), d, d + 5] {
                let mut keys = Vec::new();
                let mut radix = Vec::new();
                let mut out = Vec::new();
                argsort_prefix_desc_abs_into(&v, take, &mut keys, &mut radix, &mut out);
                assert_eq!(out, full[..take.min(d)].to_vec(), "d={d} take={take}");
            }
        }
    }

    #[test]
    fn argsort_desc() {
        let v = [1.0f32, -5.0, 3.0];
        assert_eq!(argsort_desc_abs(&v), vec![1, 2, 0]);
    }

    #[test]
    fn argsort_crosses_radix_threshold_consistently() {
        // same input sorted by both paths (radix kicks in at RADIX_MIN)
        let mut rng = Rng::new(8);
        let v: Vec<f32> = (0..RADIX_MIN + 77).map(|_| rng.normal() as f32).collect();
        let via_radix = argsort_desc_abs(&v);
        let mut keys = Vec::new();
        kernels::pack_desc_keys(&v, &mut keys);
        keys.sort_unstable();
        let via_cmp: Vec<u32> = keys.iter().map(|k| *k as u32).collect();
        assert_eq!(via_radix, via_cmp);
        // constant-byte pass skipping: tiny magnitudes share high bytes
        let w: Vec<f32> = (0..RADIX_MIN + 5).map(|i| (i % 3) as f32 * 1e-30).collect();
        let mut keys2 = Vec::new();
        kernels::pack_desc_keys(&w, &mut keys2);
        keys2.sort_unstable();
        let want: Vec<u32> = keys2.iter().map(|k| *k as u32).collect();
        assert_eq!(argsort_desc_abs(&w), want);
    }

    #[test]
    fn segments() {
        assert_eq!(num_segments(10, 3), 4);
        assert_eq!(segment_bounds(10, 3, 1), (0, 3));
        assert_eq!(segment_bounds(10, 3, 4), (9, 10)); // short tail
        assert_eq!(num_segments(9, 3), 3);
        assert_eq!(num_segments(1, 1), 1);
    }

    #[test]
    fn segment_energies_sum_to_norm() {
        let mut rng = Rng::new(9);
        let v: Vec<f32> = (0..1000).map(|_| rng.normal() as f32).collect();
        let idx = argsort_desc_abs(&v);
        let sorted: Vec<f32> = idx.iter().map(|&i| v[i as usize].abs()).collect();
        let segs = segment_sq_norms(&sorted, 64);
        assert_eq!(segs.len(), num_segments(1000, 64));
        let total: f64 = segs.iter().map(|e| *e as f64).sum();
        let want: f64 = crate::tensor::sq_norm(&v);
        assert!((total - want).abs() / want < 1e-5);
        // energies of sorted segments are non-increasing
        for w in segs.windows(2) {
            assert!(w[0] >= w[1] - 1e-6);
        }
    }
}
