//! Magnitude selection utilities: top-k, argsort-by-|v|, and segment views.
//!
//! Top-k uses `select_nth_unstable` (introselect, O(d) expected) rather
//! than a full sort — on the hot path this is the difference between the
//! compressor being free vs. dominating the round (see EXPERIMENTS.md
//! §Perf). A full descending argsort is still provided for the adaptive
//! s-Top-k path when the L1 `segstats` artifact is not in play.

/// Indices of the k largest-|v| entries, in unspecified order.
/// Ties are broken arbitrarily (matches the paper: Top-k keeps *some* set
/// of k largest-magnitude coordinates).
pub fn top_k_indices(v: &[f32], k: usize) -> Vec<u32> {
    let d = v.len();
    if k == 0 {
        return Vec::new();
    }
    if k >= d {
        return (0..d as u32).collect();
    }
    let mut idx: Vec<u32> = (0..d as u32).collect();
    // nth position in DESCENDING |v| order
    idx.select_nth_unstable_by(k - 1, |&a, &b| {
        v[b as usize]
            .abs()
            .partial_cmp(&v[a as usize].abs())
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    idx.truncate(k);
    idx
}

/// Full argsort by |v| descending.
///
/// Packs `(|v| bits, index)` into one u64 per element and sorts those —
/// comparisons become single integer compares on contiguous memory
/// instead of two indirect f32 loads, which is ~3-4x faster at d = 1M
/// (EXPERIMENTS.md §Perf). |v| is non-negative, so its IEEE-754 bit
/// pattern orders identically to its value; NaNs map above everything
/// and are tolerated (they sort first, deterministically).
pub fn argsort_desc_abs(v: &[f32]) -> Vec<u32> {
    let mut keys: Vec<u64> = v
        .iter()
        .enumerate()
        .map(|(i, x)| {
            let mag = (x.abs().to_bits() as u64) << 32;
            // invert so ascending u64 order == descending |v| order,
            // and ascending index order breaks ties deterministically
            (!mag & 0xFFFF_FFFF_0000_0000) | i as u64
        })
        .collect();
    // LSD radix over the 32 key bits (4 x 8-bit passes): O(d), ~2x over
    // comparison sort at d = 1M. Small inputs use the comparison sort
    // (radix's histogram passes don't amortize).
    if keys.len() >= 1 << 14 {
        radix_sort_by_high32(&mut keys);
    } else {
        keys.sort_unstable();
    }
    keys.into_iter().map(|k| k as u32).collect()
}

/// Stable LSD radix sort of packed `(key << 32) | idx` entries by the
/// high 32 bits. The low 32 bits (indices) ride along, preserving the
/// deterministic tie order from the packing.
fn radix_sort_by_high32(keys: &mut Vec<u64>) {
    let n = keys.len();
    let mut buf: Vec<u64> = vec![0; n];
    let mut src: &mut Vec<u64> = keys;
    let mut dst: &mut Vec<u64> = &mut buf;
    for pass in 0..4u32 {
        let shift = 32 + pass * 8;
        let mut hist = [0usize; 256];
        for k in src.iter() {
            hist[((k >> shift) & 0xFF) as usize] += 1;
        }
        let mut offsets = [0usize; 256];
        let mut acc = 0usize;
        for (o, h) in offsets.iter_mut().zip(&hist) {
            *o = acc;
            acc += h;
        }
        for k in src.iter() {
            let b = ((k >> shift) & 0xFF) as usize;
            dst[offsets[b]] = *k;
            offsets[b] += 1;
        }
        std::mem::swap(&mut src, &mut dst);
    }
    // 4 passes = even number of swaps: result is back in `keys`
}

/// Segment bounds for segment `l` (1-based, paper notation) of a length-d
/// vector split into ceil(d/s) segments of size s (last may be short).
pub fn segment_bounds(d: usize, s: usize, l: usize) -> (usize, usize) {
    debug_assert!(l >= 1);
    let lo = (l - 1) * s;
    let hi = (lo + s).min(d);
    (lo.min(d), hi)
}

/// Number of segments L = ceil(d/s).
pub fn num_segments(d: usize, s: usize) -> usize {
    d.div_ceil(s)
}

/// Squared norms of every segment of `sorted_vals` (already ordered by
/// |v| descending): `out[l-1] = (Delta^l)^2` of Lemma 3.4. This is the
/// rust-native fallback for the L1 `seg_energy` Pallas kernel.
pub fn segment_sq_norms(sorted_vals: &[f32], s: usize) -> Vec<f32> {
    let d = sorted_vals.len();
    let nl = num_segments(d, s);
    let mut out = Vec::with_capacity(nl);
    for l in 1..=nl {
        let (lo, hi) = segment_bounds(d, s, l);
        let e: f64 = sorted_vals[lo..hi]
            .iter()
            .map(|v| (*v as f64) * (*v as f64))
            .sum();
        out.push(e as f32);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn top_k_basic() {
        let v = [1.0f32, -5.0, 3.0, 0.5, -2.0];
        let mut k2 = top_k_indices(&v, 2);
        k2.sort_unstable();
        assert_eq!(k2, vec![1, 2]); // |-5|, |3|
    }

    #[test]
    fn top_k_edges() {
        let v = [1.0f32, 2.0, 3.0];
        assert!(top_k_indices(&v, 0).is_empty());
        assert_eq!(top_k_indices(&v, 3).len(), 3);
        assert_eq!(top_k_indices(&v, 10).len(), 3);
        assert!(top_k_indices(&[], 0).is_empty());
    }

    #[test]
    fn top_k_matches_sort() {
        let mut rng = Rng::new(1);
        for _ in 0..20 {
            let d = 1 + rng.below(500);
            let k = rng.below(d + 1);
            let v: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
            let mut got = top_k_indices(&v, k);
            got.sort_unstable();
            let mut want = argsort_desc_abs(&v)[..k].to_vec();
            want.sort_unstable();
            // compare magnitudes not indices (ties may differ)
            let gm: Vec<f32> = got.iter().map(|&i| v[i as usize].abs()).collect();
            let wm: Vec<f32> = want.iter().map(|&i| v[i as usize].abs()).collect();
            let mut gm2 = gm.clone();
            let mut wm2 = wm.clone();
            gm2.sort_by(|a, b| a.partial_cmp(b).unwrap());
            wm2.sort_by(|a, b| a.partial_cmp(b).unwrap());
            assert_eq!(gm2, wm2);
        }
    }

    #[test]
    fn argsort_desc() {
        let v = [1.0f32, -5.0, 3.0];
        assert_eq!(argsort_desc_abs(&v), vec![1, 2, 0]);
    }

    #[test]
    fn segments() {
        assert_eq!(num_segments(10, 3), 4);
        assert_eq!(segment_bounds(10, 3, 1), (0, 3));
        assert_eq!(segment_bounds(10, 3, 4), (9, 10)); // short tail
        assert_eq!(num_segments(9, 3), 3);
        assert_eq!(num_segments(1, 1), 1);
    }

    #[test]
    fn segment_energies_sum_to_norm() {
        let mut rng = Rng::new(9);
        let v: Vec<f32> = (0..1000).map(|_| rng.normal() as f32).collect();
        let idx = argsort_desc_abs(&v);
        let sorted: Vec<f32> = idx.iter().map(|&i| v[i as usize].abs()).collect();
        let segs = segment_sq_norms(&sorted, 64);
        assert_eq!(segs.len(), num_segments(1000, 64));
        let total: f64 = segs.iter().map(|e| *e as f64).sum();
        let want: f64 = crate::tensor::sq_norm(&v);
        assert!((total - want).abs() / want < 1e-5);
        // energies of sorted segments are non-increasing
        for w in segs.windows(2) {
            assert!(w[0] >= w[1] - 1e-6);
        }
    }
}
