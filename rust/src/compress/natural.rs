//! Natural compression (Horváth et al. 2022, cited in §1.1): stochastic
//! rounding of each element to one of its two neighbouring powers of two.
//! Unbiased with ω = 1/8, and each element ships sign + 8-bit exponent
//! = 9 bits (f32 instantiation of the paper's C_nat).

use super::{Compressed, Compressor, Payload};
use crate::tensor::Rng;

#[derive(Clone, Debug, Default)]
pub struct Natural;

/// Round `x` to a neighbouring power of two, stochastically so the
/// expectation is exact: x = sign·2^e·m with m ∈ [1,2) maps to
/// 2^e w.p. (2 − m) and 2^{e+1} w.p. (m − 1).
pub fn natural_round(x: f32, rng: &mut Rng) -> f32 {
    if x == 0.0 || !x.is_finite() {
        return x;
    }
    let mag = x.abs();
    // Exact floor-power-of-two straight from the bit pattern (libm's
    // log2/powi rounding is platform-dependent, which the
    // float-determinism lint bans in compress/): clearing the mantissa
    // of a normal float leaves exactly 2^e; for a subnormal the top set
    // bit of the raw word is already that power of two.
    let b = mag.to_bits();
    let lo = if b >= 0x0080_0000 {
        f32::from_bits(b & 0xFF80_0000)
    } else {
        f32::from_bits(1u32 << (31 - b.leading_zeros()))
    };
    let hi = lo * 2.0;
    let p_hi = (mag - lo) / (hi - lo);
    let mag_q = if (rng.uniform() as f32) < p_hi { hi } else { lo };
    mag_q.copysign(x)
}

impl Compressor for Natural {
    fn name(&self) -> String {
        "natural".into()
    }

    fn compress(&self, v: &[f32], rng: &mut Rng) -> Compressed {
        let val = v.iter().map(|x| natural_round(*x, rng)).collect();
        Compressed {
            payload: Payload::Quantized {
                val,
                bits_per_elem: 9.0, // sign + f32 exponent
                overhead_bits: 0,
            },
            extra_bits: 0,
        }
    }

    fn unbiased(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::measure;

    #[test]
    fn rounds_to_powers_of_two() {
        let mut rng = Rng::new(1);
        for _ in 0..1000 {
            let x = rng.normal() as f32 * 10.0;
            if x == 0.0 {
                continue;
            }
            let q = natural_round(x, &mut rng);
            let l = q.abs().log2();
            assert!((l - l.round()).abs() < 1e-5, "{x} -> {q}");
            assert_eq!(q.signum(), x.signum());
            // neighbouring powers: q/|x| ∈ [1/2, 2]
            let r = q.abs() / x.abs();
            assert!((0.5..=2.0).contains(&r), "{x} -> {q}");
        }
    }

    #[test]
    fn unbiased_per_element() {
        let mut rng = Rng::new(2);
        for &x in &[0.3f32, 1.0, 1.5, -2.7, 100.0, -1e-4] {
            let n = 60_000;
            let mean: f64 =
                (0..n).map(|_| natural_round(x, &mut rng) as f64).sum::<f64>() / n as f64;
            assert!(
                (mean - x as f64).abs() < 0.02 * x.abs() as f64 + 1e-7,
                "x={x} mean={mean}"
            );
        }
    }

    #[test]
    fn omega_bound() {
        // Horváth et al.: E‖C(v) − v‖² ≤ (1/8)‖v‖²
        let mut rng = Rng::new(3);
        let v: Vec<f32> = (0..256).map(|_| rng.normal() as f32).collect();
        let s = measure(&Natural, &v, 3000, 5);
        assert!(s.rel_distortion <= 0.125 + 0.01, "{}", s.rel_distortion);
        assert!(s.rel_bias < 0.05);
    }

    #[test]
    fn wire_cost_9_bits() {
        let v = vec![1.0f32; 100];
        let mut rng = Rng::new(0);
        assert_eq!(Natural.compress(&v, &mut rng).wire_bits(), 900);
    }

    #[test]
    fn exact_powers_fixed_points() {
        let mut rng = Rng::new(4);
        for &x in &[1.0f32, 2.0, 0.5, -4.0] {
            assert_eq!(natural_round(x, &mut rng), x);
        }
    }
}
