//! Reusable scratch buffers for the gradient hot path.
//!
//! A [`ScratchArena`] is a set of LIFO pools of plain `Vec`s. The
//! arena-aware entry points ([`crate::compress::Compressor::compress_with`],
//! [`crate::wire::decode_in`], [`crate::mlmc::Multilevel::draw_in`])
//! *take* buffers from the pools instead of allocating, and finished
//! payloads are *recycled* back ([`ScratchArena::recycle`]) once the
//! server has consumed them. Because a steady-state round takes and
//! returns buffers in a deterministic sequence, every pool converges to
//! its peak capacity after a warmup round or two — from then on the
//! single-thread-per-worker gradient path performs **zero heap
//! allocations** (asserted by `tests/alloc_zero.rs`).
//!
//! Ownership rules:
//!
//! * a buffer taken from the arena is owned by the taker — the arena
//!   never aliases it; return it with the matching `put_*` (or let a
//!   payload built from it flow to [`ScratchArena::recycle`]);
//! * dropping a taken buffer instead of returning it is always *safe* —
//!   it merely reintroduces an allocation on the next take;
//! * the arena is deliberately `!Sync`-shaped (plain `&mut` API): use
//!   one arena per worker thread. The multi-threaded `ParCompressor`
//!   path keeps its scoped-thread allocations (thread spawn allocates
//!   anyway); the zero-allocation contract is per-thread.
//!
//! Known allocators that remain outside the contract: the
//! boxed-context MLMC fallback for multilevel families without a
//! [`crate::mlmc::Multilevel::draw_in`] override. (`RandK` used to be
//! on this list for its lazy Fisher–Yates `HashMap`; its scratch is now
//! a sorted arena-lent `u64` buffer, see [`crate::tensor::Rng::choose_k_with`].)
//! See README §"Hot path".

use super::{Compressed, Payload};
use crate::tensor::Rng;

/// Pools of reusable buffers. See the module docs for ownership rules.
#[derive(Default)]
pub struct ScratchArena {
    f32s: Vec<Vec<f32>>,
    u32s: Vec<Vec<u32>>,
    u64s: Vec<Vec<u64>>,
    bytes: Vec<Vec<u8>>,
    payloads: Vec<Vec<Payload>>,
    rngs: Vec<Vec<Rng>>,
}

/// Pop from a pool (or make a fresh `Vec`), cleared, with at least
/// `cap` capacity reserved.
macro_rules! take_impl {
    ($pool:expr, $cap:expr) => {{
        let mut v = $pool.pop().unwrap_or_default();
        v.clear();
        v.reserve($cap);
        v
    }};
}

impl ScratchArena {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn take_f32(&mut self, cap: usize) -> Vec<f32> {
        take_impl!(self.f32s, cap)
    }

    pub fn put_f32(&mut self, v: Vec<f32>) {
        self.f32s.push(v);
    }

    pub fn take_u32(&mut self, cap: usize) -> Vec<u32> {
        take_impl!(self.u32s, cap)
    }

    pub fn put_u32(&mut self, v: Vec<u32>) {
        self.u32s.push(v);
    }

    pub fn take_u64(&mut self, cap: usize) -> Vec<u64> {
        take_impl!(self.u64s, cap)
    }

    pub fn put_u64(&mut self, v: Vec<u64>) {
        self.u64s.push(v);
    }

    pub fn take_bytes(&mut self, cap: usize) -> Vec<u8> {
        take_impl!(self.bytes, cap)
    }

    pub fn put_bytes(&mut self, v: Vec<u8>) {
        self.bytes.push(v);
    }

    pub fn take_payloads(&mut self, cap: usize) -> Vec<Payload> {
        take_impl!(self.payloads, cap)
    }

    pub fn put_payloads(&mut self, v: Vec<Payload>) {
        debug_assert!(v.is_empty(), "recycle payload contents first");
        self.payloads.push(v);
    }

    /// Reusable per-shard RNG stream buffer (see
    /// [`crate::tensor::Rng::shard_streams_into`]).
    pub fn take_rngs(&mut self) -> Vec<Rng> {
        self.rngs.pop().unwrap_or_default()
    }

    pub fn put_rngs(&mut self, v: Vec<Rng>) {
        self.rngs.push(v);
    }

    // repolint: no_alloc(start) — recycling hands buffers back to the
    // pools; it must never allocate (that is the whole point of the
    // arena's steady-state contract).
    /// Return a consumed message's buffers to the pools.
    pub fn recycle(&mut self, c: Compressed) {
        self.recycle_payload(c.payload);
    }

    /// Return a consumed payload's buffers to the pools (recurses into
    /// sharded payloads).
    pub fn recycle_payload(&mut self, p: Payload) {
        match p {
            Payload::Dense(v) | Payload::Quantized { val: v, .. } => self.put_f32(v),
            Payload::Sparse { idx, val, .. } => {
                self.put_u32(idx);
                self.put_f32(val);
            }
            Payload::Sharded(mut parts) => {
                for part in parts.drain(..) {
                    self.recycle_payload(part);
                }
                self.put_payloads(parts);
            }
        }
    }
    // repolint: no_alloc(end)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_is_cleared_and_reserved() {
        let mut a = ScratchArena::new();
        let mut v = a.take_f32(16);
        v.extend_from_slice(&[1.0, 2.0, 3.0]);
        a.put_f32(v);
        let v2 = a.take_f32(16);
        assert!(v2.is_empty());
        assert!(v2.capacity() >= 16);
    }

    #[test]
    fn pools_reuse_lifo() {
        let mut a = ScratchArena::new();
        let mut v = a.take_u32(8);
        v.push(1);
        let p = v.as_ptr();
        a.put_u32(v);
        let v2 = a.take_u32(4);
        // same backing store comes back (capacity already sufficient)
        assert_eq!(v2.as_ptr(), p);
    }

    #[test]
    fn recycle_dismantles_sharded_payloads() {
        let mut a = ScratchArena::new();
        let c = Compressed {
            payload: Payload::Sharded(vec![
                Payload::Dense(vec![1.0, 2.0]),
                Payload::Sparse { d: 4, idx: vec![1], val: vec![3.0] },
                Payload::Quantized { val: vec![0.5], bits_per_elem: 2.0, overhead_bits: 32 },
            ]),
            extra_bits: 0,
        };
        a.recycle(c);
        assert_eq!(a.f32s.len(), 3);
        assert_eq!(a.u32s.len(), 1);
        assert_eq!(a.payloads.len(), 1);
    }
}
