//! Bit-wise compressors (paper §3.1, App. B): fixed-point and
//! floating-point truncation.
//!
//! The paper's formulas assume 64-bit scalars (63 fixed-point levels, 52
//! mantissa bits); our gradients are f32, so the native depths are 23/23
//! and the headline "×32" uncompressed-to-2-bit ratio becomes "×16"
//! (32-bit baselines). All closed forms are parameterized on the depth so
//! the paper's numbers are recovered by plugging in 64-bit widths — see
//! EXPERIMENTS.md `comm` rows.

use super::{Compressed, Compressor, Payload, ScratchArena};
use crate::tensor::{kernels, max_abs, Rng};

/// Maximum meaningful fixed-point depth for f32 gradients.
pub const FX_MAX_LEVELS: usize = 23;
/// f32 mantissa width = maximum floating-point truncation depth.
pub const FP_MANTISSA_BITS: usize = 23;

/// Truncate `|e| <= 1` to its first `l` fractional bits (Eq. (7) truncated).
#[inline]
pub fn fx_truncate_norm(e: f32, pow2: f32) -> f32 {
    e.signum() * (e.abs() * pow2).floor() / pow2
}

/// Fixed-point compressor: normalize by the max entry, keep `f` fractional
/// bits per element. Biased; distortion ≤ 2^-f per normalized entry.
///
/// Wire cost: `(f + 1) * d` bits (f info + 1 sign) + 32 for the scale.
#[derive(Clone, Debug)]
pub struct FixedPoint {
    pub f: usize,
}

impl FixedPoint {
    /// Apply at depth `f` and scale; shared with the multilevel wrapper.
    pub fn apply_with_scale(v: &[f32], f: usize, scale: f32) -> Vec<f32> {
        let mut out = Vec::with_capacity(v.len());
        Self::apply_with_scale_into(v, f, scale, &mut out);
        out
    }

    /// [`FixedPoint::apply_with_scale`] into a caller-owned buffer
    /// (cleared first), routed through the vectorized truncation kernel.
    pub fn apply_with_scale_into(v: &[f32], f: usize, scale: f32, out: &mut Vec<f32>) {
        out.clear();
        out.resize(v.len(), 0.0);
        if scale == 0.0 {
            return;
        }
        let pow2 = (1u64 << f.min(63)) as f32;
        kernels::fx_apply(out, v, pow2, scale);
    }
}

impl Compressor for FixedPoint {
    fn name(&self) -> String {
        format!("fxp(f={})", self.f)
    }

    fn compress(&self, v: &[f32], rng: &mut Rng) -> Compressed {
        self.compress_with(v, rng, &mut ScratchArena::new())
    }

    fn compress_with(&self, v: &[f32], _rng: &mut Rng, arena: &mut ScratchArena) -> Compressed {
        let scale = max_abs(v);
        let mut val = arena.take_f32(v.len());
        Self::apply_with_scale_into(v, self.f, scale, &mut val);
        Compressed {
            payload: Payload::Quantized {
                val,
                bits_per_elem: (self.f + 1) as f64,
                overhead_bits: 32,
            },
            extra_bits: 0,
        }
    }

    fn unbiased(&self) -> bool {
        false
    }
}

/// Floating-point compressor (App. B): keep sign, exponent, and the top
/// `f` mantissa bits of each f32 (truncation toward zero).
///
/// Wire cost: `(1 + 8 + f) * d` bits (f32 exponent is 8 bits; the paper's
/// f64 analysis has 11).
#[derive(Clone, Debug)]
pub struct FloatPoint {
    pub f: usize,
}

impl FloatPoint {
    /// Truncate one f32's mantissa to `f` bits.
    #[inline]
    pub fn truncate_elem(x: f32, f: usize) -> f32 {
        if f >= FP_MANTISSA_BITS {
            return x;
        }
        let mask: u32 = !((1u32 << (FP_MANTISSA_BITS - f)) - 1);
        f32::from_bits(x.to_bits() & mask)
    }

    pub fn apply(v: &[f32], f: usize) -> Vec<f32> {
        let mut out = Vec::with_capacity(v.len());
        Self::apply_into(v, f, &mut out);
        out
    }

    /// [`FloatPoint::apply`] into a caller-owned buffer (cleared first),
    /// routed through the vectorized bit-mask kernel. `f >=`
    /// [`FP_MANTISSA_BITS`] degenerates to an all-ones mask (lossless),
    /// matching [`FloatPoint::truncate_elem`] bit-for-bit.
    pub fn apply_into(v: &[f32], f: usize, out: &mut Vec<f32>) {
        out.clear();
        out.resize(v.len(), 0.0);
        let mask: u32 = if f >= FP_MANTISSA_BITS {
            !0
        } else {
            !((1u32 << (FP_MANTISSA_BITS - f)) - 1)
        };
        kernels::fp_truncate(out, v, mask);
    }
}

impl Compressor for FloatPoint {
    fn name(&self) -> String {
        format!("flp(f={})", self.f)
    }

    fn compress(&self, v: &[f32], rng: &mut Rng) -> Compressed {
        self.compress_with(v, rng, &mut ScratchArena::new())
    }

    fn compress_with(&self, v: &[f32], _rng: &mut Rng, arena: &mut ScratchArena) -> Compressed {
        let mut val = arena.take_f32(v.len());
        Self::apply_into(v, self.f, &mut val);
        Compressed {
            payload: Payload::Quantized {
                val,
                bits_per_elem: (1 + 8 + self.f) as f64,
                overhead_bits: 0,
            },
            extra_bits: 0,
        }
    }

    fn unbiased(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{sq_dist, sq_norm};

    fn test_vec(d: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..d).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn fx_truncate_matches_python_oracle() {
        // pinned vectors from python/compile/kernels/ref.py semantics
        assert_eq!(fx_truncate_norm(0.75, 2.0), 0.5);
        assert_eq!(fx_truncate_norm(-0.75, 2.0), -0.5);
        assert_eq!(fx_truncate_norm(1.0, 2.0), 1.0);
        assert_eq!(fx_truncate_norm(0.0, 2.0), 0.0);
    }

    #[test]
    fn fixed_point_distortion_bound() {
        // per-element distortion ≤ 2^-f * scale
        let v = test_vec(512, 1);
        let scale = max_abs(&v);
        for f in [1usize, 2, 8, 16] {
            let dec = FixedPoint::apply_with_scale(&v, f, scale);
            for (a, b) in dec.iter().zip(&v) {
                assert!((a - b).abs() <= 2f32.powi(-(f as i32)) * scale + 1e-7);
            }
        }
    }

    #[test]
    fn fixed_point_wire_cost() {
        let v = test_vec(100, 2);
        let mut rng = Rng::new(0);
        let c = FixedPoint { f: 1 }.compress(&v, &mut rng);
        // "2-bit quantization": 2 bits/elem + 32-bit scale
        assert_eq!(c.wire_bits(), 2 * 100 + 32);
    }

    #[test]
    fn fixed_point_zero_vector() {
        let v = vec![0.0f32; 16];
        let mut rng = Rng::new(0);
        let dec = FixedPoint { f: 4 }.compress(&v, &mut rng).decode();
        assert_eq!(dec, v);
    }

    #[test]
    fn fixed_point_biased_toward_zero() {
        // truncation shrinks magnitudes: |C(v)_i| <= |v_i|
        let v = test_vec(256, 3);
        let mut rng = Rng::new(0);
        let dec = FixedPoint { f: 3 }.compress(&v, &mut rng).decode();
        for (a, b) in dec.iter().zip(&v) {
            assert!(a.abs() <= b.abs() + 1e-7);
            assert!(a.signum() * b.signum() >= 0.0);
        }
    }

    #[test]
    fn float_point_truncation() {
        // 1.75 = 1.11_2 ; keeping 1 mantissa bit → 1.5
        assert_eq!(FloatPoint::truncate_elem(1.75, 1), 1.5);
        assert_eq!(FloatPoint::truncate_elem(-1.75, 1), -1.5);
        // full mantissa is lossless
        assert_eq!(FloatPoint::truncate_elem(1.2345678, 23), 1.2345678);
        assert_eq!(FloatPoint::truncate_elem(0.0, 4), 0.0);
    }

    #[test]
    fn float_point_alpha_bound() {
        // App. B: satisfies Eq. (4) with α = 1 − 2^-f... i.e. distortion
        // ≤ 2^-f ||v||² — relative per-element error ≤ 2^-f
        let v = test_vec(512, 5);
        for f in [1usize, 4, 10] {
            let dec = FloatPoint::apply(&v, f);
            let rel = sq_dist(&dec, &v) / sq_norm(&v);
            // distortion of mantissa truncation ≤ (2^-f)² per unit energy,
            // very loose check against the paper's (1−α) = 2^-f bound:
            assert!(rel <= 2f64.powi(-(f as i32)), "f={f} rel={rel}");
        }
    }

    #[test]
    fn float_point_wire_cost() {
        let v = test_vec(10, 7);
        let mut rng = Rng::new(0);
        let c = FloatPoint { f: 1 }.compress(&v, &mut rng);
        assert_eq!(c.wire_bits(), 10 * 10); // (1+8+1) * d
    }
}
