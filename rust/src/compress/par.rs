//! Sharded, multi-threaded compression: run any [`Compressor`] per-shard
//! across a scoped thread pool.
//!
//! [`ParCompressor`] splits the gradient into [`ShardSpec`] chunks,
//! derives one deterministic RNG stream per shard
//! ([`Rng::shard_streams`] — the `(seed, worker, step, shard)` stream
//! contract), compresses every shard independently, and reassembles the
//! per-shard messages into a single framed [`super::Payload::Sharded`]
//! message via [`Compressed::sharded`].
//!
//! Because shard boundaries and per-shard RNG streams are pure functions
//! of the input — never of the thread schedule — the output is
//! **bit-identical for any thread count** (property-tested in
//! `tests/prop_invariants.rs`).
//!
//! Semantics note: per-shard compression is *not* the same operator as
//! whole-vector compression. Per-shard Top-k keeps k coordinates in
//! every shard — a block-compression scheme in the sense of the
//! shifted/block compression literature (Shulgin & Richtárik 2022) —
//! and quantizers compute their scales per shard. What *is* preserved
//! is unbiasedness: if the inner compressor is unbiased on each shard
//! (Eq. (3), or MLMC's Lemma 3.2 per shard), the concatenated estimate
//! is unbiased on the full vector, since expectation acts coordinatewise.

use super::{shard_framing_bits, Compressed, Compressor, Payload, ScratchArena};
use crate::tensor::{Rng, ShardSpec};

/// Adapter that runs `inner` independently on every shard of the input.
pub struct ParCompressor {
    inner: Box<dyn Compressor>,
    shard_size: usize,
    threads: usize,
}

impl ParCompressor {
    /// `shard_size` and `threads` are clamped to `>= 1`.
    pub fn new(inner: Box<dyn Compressor>, shard_size: usize, threads: usize) -> Self {
        ParCompressor { inner, shard_size: shard_size.max(1), threads: threads.max(1) }
    }

    pub fn shard_size(&self) -> usize {
        self.shard_size
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// The shard geometry this compressor applies to a length-`d` input.
    pub fn spec(&self, d: usize) -> ShardSpec {
        ShardSpec::new(d, self.shard_size)
    }
}

impl Compressor for ParCompressor {
    fn name(&self) -> String {
        format!("sharded[{} s={} t={}]", self.inner.name(), self.shard_size, self.threads)
    }

    fn compress(&self, v: &[f32], rng: &mut Rng) -> Compressed {
        let spec = self.spec(v.len());
        let n = spec.num_shards();
        let mut rngs = rng.shard_streams(n);
        let mut parts: Vec<Option<Compressed>> = vec![None; n];
        let threads = self.threads.min(n.max(1));
        if threads <= 1 {
            for (i, (slot, r)) in parts.iter_mut().zip(rngs.iter_mut()).enumerate() {
                *slot = Some(self.inner.compress(&v[spec.range(i)], r));
            }
        } else {
            let chunk = n.div_ceil(threads);
            let inner: &dyn Compressor = &*self.inner;
            std::thread::scope(|s| {
                for ((t, slots), shard_rngs) in
                    parts.chunks_mut(chunk).enumerate().zip(rngs.chunks_mut(chunk))
                {
                    s.spawn(move || {
                        for (j, (slot, r)) in
                            slots.iter_mut().zip(shard_rngs.iter_mut()).enumerate()
                        {
                            let i = t * chunk + j;
                            *slot = Some(inner.compress(&v[spec.range(i)], r));
                        }
                    });
                }
            });
        }
        Compressed::sharded(parts.into_iter().map(|p| p.expect("all shards compressed")).collect())
    }

    fn compress_with(&self, v: &[f32], rng: &mut Rng, arena: &mut ScratchArena) -> Compressed {
        let spec = self.spec(v.len());
        let n = spec.num_shards();
        let threads = self.threads.min(n.max(1));
        if threads > 1 {
            // scoped-thread spawning allocates regardless; the arena
            // contract is per-thread, so the pooled path keeps the
            // allocating form (still bit-identical — same streams).
            return self.compress(v, rng);
        }
        let mut rngs = arena.take_rngs();
        rng.shard_streams_into(n, &mut rngs);
        let mut parts = arena.take_payloads(n);
        let mut extra: u64 = 0;
        for (i, r) in rngs.iter_mut().enumerate() {
            let c = self.inner.compress_with(&v[spec.range(i)], r, arena);
            extra += c.extra_bits;
            parts.push(c.payload);
        }
        arena.put_rngs(rngs);
        // same accounting as [`Compressed::sharded`]
        Compressed {
            payload: Payload::Sharded(parts),
            extra_bits: extra + shard_framing_bits(n),
        }
    }

    fn unbiased(&self) -> bool {
        self.inner.unbiased()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{shard_framing_bits, Identity, TopK};

    fn grad(d: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..d).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn identity_sharded_is_exact() {
        let v = grad(103, 1);
        let par = ParCompressor::new(Box::new(Identity), 16, 3);
        let mut rng = Rng::new(0);
        let c = par.compress(&v, &mut rng);
        assert_eq!(c.decode(), v);
        assert_eq!(c.dim(), v.len());
        // 7 shards of dense f32 + framing
        assert_eq!(c.wire_bits(), 32 * 103 + shard_framing_bits(7));
        assert!(par.unbiased());
    }

    #[test]
    fn thread_count_does_not_change_bits() {
        let v = grad(501, 2);
        for shard in [1usize, 7, 64, 501, 1000] {
            let mut decs: Vec<Vec<f32>> = Vec::new();
            for threads in [1usize, 2, 5] {
                let par = ParCompressor::new(Box::new(TopK { k: 3 }), shard, threads);
                let mut rng = Rng::new(42);
                decs.push(par.compress(&v, &mut rng).decode());
            }
            for d in &decs[1..] {
                assert_eq!(&decs[0], d, "shard={shard}");
            }
        }
    }

    #[test]
    fn per_shard_topk_keeps_k_per_shard() {
        let v = grad(100, 3);
        let par = ParCompressor::new(Box::new(TopK { k: 2 }), 25, 2);
        let mut rng = Rng::new(0);
        let dec = par.compress(&v, &mut rng).decode();
        for (s, range) in par.spec(v.len()).ranges().enumerate() {
            let nz = dec[range].iter().filter(|x| **x != 0.0).count();
            assert_eq!(nz, 2, "shard {s}");
        }
    }

    #[test]
    fn empty_input_yields_empty_message() {
        let par = ParCompressor::new(Box::new(Identity), 8, 4);
        let mut rng = Rng::new(0);
        let c = par.compress(&[], &mut rng);
        assert_eq!(c.dim(), 0);
        assert!(c.decode().is_empty());
    }
}
