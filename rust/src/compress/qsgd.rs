//! QSGD (Alistarh et al. 2017): unbiased stochastic quantization.
//!
//! Each coordinate is quantized to one of `s+1` magnitude levels of
//! `‖v‖₂` with stochastic rounding, which makes the estimator exactly
//! unbiased. `s = 1` is the "2-bit QSGD" comparator of Fig. 3
//! (1 sign bit + 1 magnitude bit per element + the 32-bit norm).

use super::{Compressed, Compressor, Payload};
use crate::tensor::{norm, Rng};

#[derive(Clone, Debug)]
pub struct Qsgd {
    /// number of positive quantization intervals
    pub s: u32,
}

impl Compressor for Qsgd {
    fn name(&self) -> String {
        format!("qsgd(s={})", self.s)
    }

    fn compress(&self, v: &[f32], rng: &mut Rng) -> Compressed {
        let n = norm(v) as f32;
        let s = self.s.max(1) as f32;
        let val: Vec<f32> = if n == 0.0 {
            vec![0.0; v.len()]
        } else {
            v.iter()
                .map(|x| {
                    let r = x.abs() / n * s; // in [0, s]
                    let lo = r.floor();
                    let p = r - lo;
                    let q = if (rng.uniform() as f32) < p { lo + 1.0 } else { lo };
                    x.signum() * n * q / s
                })
                .collect()
        };
        // ceil(log2(s+1)) magnitude bits + 1 sign bit per element
        let mag_bits = (32 - self.s.max(1).leading_zeros()) as f64;
        Compressed {
            payload: Payload::Quantized {
                val,
                bits_per_elem: mag_bits + 1.0,
                overhead_bits: 32,
            },
            extra_bits: 0,
        }
    }

    fn unbiased(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::measure;

    fn test_vec(d: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..d).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn qsgd_unbiased() {
        let v = test_vec(64, 1);
        let s = measure(&Qsgd { s: 1 }, &v, 6000, 3);
        assert!(s.rel_bias < 0.05, "bias {}", s.rel_bias);
    }

    #[test]
    fn qsgd_levels_on_grid() {
        let v = test_vec(128, 2);
        let mut rng = Rng::new(0);
        let q = Qsgd { s: 4 };
        let n = norm(&v) as f32;
        let dec = q.compress(&v, &mut rng).decode();
        for x in &dec {
            let units = x.abs() / n * 4.0;
            assert!((units - units.round()).abs() < 1e-5, "{units}");
            assert!(units.round() <= 4.0);
        }
    }

    #[test]
    fn qsgd_two_bit_cost() {
        let v = test_vec(100, 3);
        let mut rng = Rng::new(0);
        let c = Qsgd { s: 1 }.compress(&v, &mut rng);
        assert_eq!(c.wire_bits(), 2 * 100 + 32); // "2-bit QSGD"
    }

    #[test]
    fn qsgd_finer_grid_lower_distortion() {
        let v = test_vec(256, 5);
        let coarse = measure(&Qsgd { s: 1 }, &v, 500, 7).rel_distortion;
        let fine = measure(&Qsgd { s: 16 }, &v, 500, 7).rel_distortion;
        assert!(fine < coarse, "{fine} !< {coarse}");
    }

    #[test]
    fn qsgd_zero_vector() {
        let v = vec![0.0f32; 8];
        let mut rng = Rng::new(0);
        assert_eq!(Qsgd { s: 2 }.compress(&v, &mut rng).decode(), v);
    }

    #[test]
    fn qsgd_variance_bound() {
        // E||C(v) − v||² ≤ min(d/s², √d/s)||v||² (QSGD paper Lemma 3.1)
        let v = test_vec(64, 9);
        let s = measure(&Qsgd { s: 2 }, &v, 2000, 11);
        let d = 64.0f64;
        let bound = (d / 4.0).min(d.sqrt() / 2.0);
        assert!(s.rel_distortion <= bound, "{} > {bound}", s.rel_distortion);
    }
}
