//! SignSGD with l1 scaling (Bernstein et al. 2018; Seide et al. 2014):
//! `C(v) = sign(v) · ‖v‖₁/d` — 1 bit per element + one 32-bit scale.
//! Biased; the classic EF use case.

use super::{Compressed, Compressor, Payload, ScratchArena};
use crate::tensor::{kernels, l1_norm, Rng};

#[derive(Clone, Debug, Default)]
pub struct SignSgd;

impl Compressor for SignSgd {
    fn name(&self) -> String {
        "sign".into()
    }

    fn compress(&self, v: &[f32], rng: &mut Rng) -> Compressed {
        self.compress_with(v, rng, &mut ScratchArena::new())
    }

    fn compress_with(&self, v: &[f32], _rng: &mut Rng, arena: &mut ScratchArena) -> Compressed {
        let d = v.len();
        let mag = if d == 0 { 0.0 } else { (l1_norm(v) / d as f64) as f32 };
        let mut val = arena.take_f32(d);
        val.resize(d, 0.0);
        kernels::sign_fill(&mut val, v, mag);
        Compressed {
            payload: Payload::Quantized { val, bits_per_elem: 1.0, overhead_bits: 32 },
            extra_bits: 0,
        }
    }

    fn unbiased(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sign_magnitude_and_cost() {
        let v = [1.0f32, -3.0, 2.0, -2.0];
        let mut rng = Rng::new(0);
        let c = SignSgd.compress(&v, &mut rng);
        let dec = c.decode();
        assert_eq!(dec, vec![2.0, -2.0, 2.0, -2.0]);
        assert_eq!(c.wire_bits(), 4 + 32);
    }

    #[test]
    fn sign_contraction_property() {
        // ||C(v) − v||² < ||v||² for any v with ‖v‖₁ > 0 (δ-compressor)
        let mut rng = Rng::new(5);
        for _ in 0..20 {
            let v: Vec<f32> = (0..64).map(|_| rng.normal() as f32).collect();
            let dec = SignSgd.compress(&v, &mut rng).decode();
            assert!(crate::tensor::sq_dist(&dec, &v) < crate::tensor::sq_norm(&v));
        }
    }

    #[test]
    fn sign_empty_and_zero() {
        let mut rng = Rng::new(0);
        assert!(SignSgd.compress(&[], &mut rng).decode().is_empty());
        let dec = SignSgd.compress(&[0.0, 0.0], &mut rng).decode();
        assert_eq!(dec, vec![0.0, 0.0]);
    }
}
