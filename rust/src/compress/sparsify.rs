//! Sparsification compressors: Top-k (biased), s-Top-k (biased,
//! the paper's segmented generalization, §2.2), Rand-k (unbiased).

use super::{Compressed, Compressor, Payload, ScratchArena};
use crate::tensor::kernels;
use crate::tensor::select::{
    argsort_prefix_desc_abs_into, num_segments, segment_bounds, top_k_indices_into,
};
use crate::tensor::Rng;

/// Top-k: keep the k largest-magnitude coordinates (biased, α = k/d).
#[derive(Clone, Debug)]
pub struct TopK {
    pub k: usize,
}

impl Compressor for TopK {
    fn name(&self) -> String {
        format!("topk(k={})", self.k)
    }

    fn compress(&self, v: &[f32], rng: &mut Rng) -> Compressed {
        self.compress_with(v, rng, &mut ScratchArena::new())
    }

    fn compress_with(&self, v: &[f32], _rng: &mut Rng, arena: &mut ScratchArena) -> Compressed {
        let mut keys = arena.take_u64(v.len());
        let mut idx = arena.take_u32(self.k.min(v.len()));
        top_k_indices_into(v, self.k, &mut keys, &mut idx);
        arena.put_u64(keys);
        let mut val = arena.take_f32(idx.len());
        kernels::gather(v, &idx, &mut val);
        Compressed {
            payload: Payload::Sparse { d: v.len() as u32, idx, val },
            extra_bits: 0,
        }
    }

    fn unbiased(&self) -> bool {
        false
    }
}

/// s-Top-k: sort by |v|, split into segments of length s, keep the k
/// segments with largest norm (biased, α = sk/d). With s = 1 this is
/// exactly Top-k.
#[derive(Clone, Debug)]
pub struct STopK {
    pub s: usize,
    pub k: usize,
}

impl Compressor for STopK {
    fn name(&self) -> String {
        format!("stopk(s={},k={})", self.s, self.k)
    }

    fn compress(&self, v: &[f32], rng: &mut Rng) -> Compressed {
        self.compress_with(v, rng, &mut ScratchArena::new())
    }

    fn compress_with(&self, v: &[f32], _rng: &mut Rng, arena: &mut ScratchArena) -> Compressed {
        let d = v.len();
        // segments of the sorted order are nested by construction: the
        // k top-norm segments are just the first k segments — so only
        // the first k*s positions of the argsort are ever shipped.
        // Partition + prefix-sort instead of a full argsort: the packed
        // keys form a strict total order, so the result (including tie
        // order) is bit-identical to the full sort's prefix while
        // skipping the O(d log d) tail work when k*s ≪ d.
        let take = (self.k * self.s).min(d);
        let mut keys = arena.take_u64(d);
        let mut radix = arena.take_u64(d);
        let mut idx = arena.take_u32(take);
        argsort_prefix_desc_abs_into(v, take, &mut keys, &mut radix, &mut idx);
        arena.put_u64(keys);
        arena.put_u64(radix);
        let mut val = arena.take_f32(take);
        kernels::gather(v, &idx, &mut val);
        Compressed {
            payload: Payload::Sparse { d: d as u32, idx, val },
            extra_bits: 0,
        }
    }

    fn unbiased(&self) -> bool {
        false
    }
}

impl STopK {
    /// Number of levels when used as a multilevel compressor.
    pub fn levels(&self, d: usize) -> usize {
        num_segments(d, self.s)
    }

    /// The l-th segment (1-based) of the sorted order: `(indices, values)`.
    pub fn segment(&self, v: &[f32], order: &[u32], l: usize) -> (Vec<u32>, Vec<f32>) {
        let (lo, hi) = segment_bounds(v.len(), self.s, l);
        let idx: Vec<u32> = order[lo..hi].to_vec();
        let val: Vec<f32> = idx.iter().map(|&i| v[i as usize]).collect();
        (idx, val)
    }
}

/// Rand-k: keep k uniformly random coordinates scaled by d/k (unbiased,
/// ω = d/k − 1).
#[derive(Clone, Debug)]
pub struct RandK {
    pub k: usize,
}

impl Compressor for RandK {
    fn name(&self) -> String {
        format!("randk(k={})", self.k)
    }

    fn compress(&self, v: &[f32], rng: &mut Rng) -> Compressed {
        self.compress_with(v, rng, &mut ScratchArena::new())
    }

    fn compress_with(&self, v: &[f32], rng: &mut Rng, arena: &mut ScratchArena) -> Compressed {
        let d = v.len();
        let k = self.k.min(d);
        let mut idx = arena.take_u32(k);
        let mut swaps = arena.take_u64(k);
        rng.choose_k_with(d, k, &mut idx, &mut swaps);
        arena.put_u64(swaps);
        let scale = d as f32 / k as f32;
        let mut val = arena.take_f32(k);
        kernels::gather_scaled(v, &idx, scale, &mut val);
        Compressed {
            payload: Payload::Sparse { d: d as u32, idx, val },
            extra_bits: 0,
        }
    }

    fn unbiased(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::measure;
    use crate::tensor::select::argsort_desc_abs;
    use crate::tensor::{sq_dist, sq_norm, Rng};

    fn test_vec(d: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..d).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn topk_keeps_largest() {
        let v = vec![0.1f32, -9.0, 0.2, 5.0, -0.3];
        let mut rng = Rng::new(0);
        let dec = TopK { k: 2 }.compress(&v, &mut rng).decode();
        assert_eq!(dec, vec![0.0, -9.0, 0.0, 5.0, 0.0]);
    }

    #[test]
    fn topk_distortion_bound() {
        // Eq. (9): ||C(v) − v||² ≤ (1 − k/d) ||v||²
        let v = test_vec(300, 3);
        let mut rng = Rng::new(0);
        for k in [1, 10, 100, 300] {
            let dec = TopK { k }.compress(&v, &mut rng).decode();
            let lhs = sq_dist(&dec, &v);
            let bound = (1.0 - k as f64 / 300.0) * sq_norm(&v);
            assert!(lhs <= bound + 1e-9, "k={k}: {lhs} > {bound}");
        }
    }

    #[test]
    fn topk_full_is_identity() {
        let v = test_vec(32, 1);
        let mut rng = Rng::new(0);
        let dec = TopK { k: 32 }.compress(&v, &mut rng).decode();
        assert_eq!(dec, v);
    }

    #[test]
    fn stopk_s1_equals_topk() {
        let v = test_vec(100, 5);
        let mut rng = Rng::new(0);
        let a = STopK { s: 1, k: 7 }.compress(&v, &mut rng).decode();
        let b = TopK { k: 7 }.compress(&v, &mut rng).decode();
        // same retained energy even if tie order differs
        assert!((sq_norm(&a) - sq_norm(&b)).abs() < 1e-9);
    }

    #[test]
    fn stopk_distortion_bound() {
        // α = sk/d
        let v = test_vec(257, 7);
        let mut rng = Rng::new(0);
        let (s, k) = (16, 5);
        let dec = STopK { s, k }.compress(&v, &mut rng).decode();
        let lhs = sq_dist(&dec, &v);
        let bound = (1.0 - (s * k) as f64 / 257.0) * sq_norm(&v);
        assert!(lhs <= bound + 1e-9);
    }

    #[test]
    fn stopk_segments_partition() {
        let v = test_vec(103, 9);
        let st = STopK { s: 10, k: 0 };
        let order = argsort_desc_abs(&v);
        let nl = st.levels(103);
        assert_eq!(nl, 11);
        let mut all: Vec<u32> = Vec::new();
        for l in 1..=nl {
            let (idx, val) = st.segment(&v, &order, l);
            assert_eq!(idx.len(), val.len());
            if l < nl {
                assert_eq!(idx.len(), 10);
            } else {
                assert_eq!(idx.len(), 3);
            }
            all.extend(&idx);
        }
        all.sort_unstable();
        assert_eq!(all, (0..103).collect::<Vec<u32>>());
    }

    #[test]
    fn stopk_prefix_matches_full_sort_reference() {
        // the partitioned fast path must equal the old full-argsort
        // implementation exactly: same indices, same order, same bits
        let mut rng = Rng::new(0);
        for (d, s, k) in [(257, 16, 3), (1000, 10, 5), (64, 8, 8), (50, 7, 100), (33, 1, 0)] {
            let v = test_vec(d, d as u64);
            let c = STopK { s, k }.compress(&v, &mut rng);
            let order = argsort_desc_abs(&v);
            let take = (k * s).min(d);
            let want_idx: Vec<u32> = order[..take].to_vec();
            let want_val: Vec<f32> = want_idx.iter().map(|&i| v[i as usize]).collect();
            match &c.payload {
                Payload::Sparse { idx, val, .. } => {
                    assert_eq!(idx, &want_idx, "d={d} s={s} k={k}");
                    assert_eq!(val, &want_val);
                }
                p => panic!("unexpected payload {p:?}"),
            }
            let want_bits = want_idx.len() as u64 * (32 + super::super::index_bits(d));
            assert_eq!(c.wire_bits(), want_bits);
        }
    }

    #[test]
    fn randk_unbiased_and_scaled() {
        let v = test_vec(64, 11);
        let s = measure(&RandK { k: 8 }, &v, 8000, 17);
        assert!(s.rel_bias < 0.06, "bias {}", s.rel_bias);
        // ω = d/k − 1 = 7: E||C(v)−v||² = (d/k −1)||v||²... check loose
        assert!(s.rel_distortion > 3.0 && s.rel_distortion < 12.0, "{}", s.rel_distortion);
    }

    #[test]
    fn randk_wire_cost() {
        let v = test_vec(1024, 2);
        let mut rng = Rng::new(0);
        let c = RandK { k: 16 }.compress(&v, &mut rng);
        assert_eq!(c.wire_bits(), 16 * (32 + 10));
    }

    #[test]
    fn randk_k_ge_d() {
        let v = test_vec(8, 0);
        let mut rng = Rng::new(0);
        let dec = RandK { k: 100 }.compress(&v, &mut rng).decode();
        assert_eq!(dec, v); // scale = 1, all coordinates
    }
}
