//! Gradient compressor library (paper §2.2).
//!
//! Every compressor maps a flat gradient `v ∈ R^d` to a [`Compressed`]
//! payload carrying (a) enough information to reconstruct the dense
//! estimate and (b) its **exact wire cost in bits** — the x-axis of
//! Figs. 1/3/4/6. Unbiased compressors satisfy Eq. (3)
//! (`E[C(v)] = v`), biased ones Eq. (4)
//! (`E‖C(v)−v‖² ≤ (1−α)‖v‖²`).
//!
//! The MLMC wrapper that turns any *multilevel* biased compressor into an
//! unbiased one lives in [`crate::mlmc`].

pub mod bitwise;
pub mod natural;
pub mod qsgd;
pub mod rtn;
pub mod sign;
pub mod sparsify;

pub use bitwise::{FixedPoint, FloatPoint};
pub use natural::Natural;
pub use qsgd::Qsgd;
pub use rtn::Rtn;
pub use sign::SignSgd;
pub use sparsify::{RandK, STopK, TopK};

use crate::tensor::Rng;

/// Bits to address one coordinate of a length-d vector.
pub fn index_bits(d: usize) -> u64 {
    (usize::BITS - d.max(2).saturating_sub(1).leading_zeros()) as u64
}

/// Abstract compressed payload.
///
/// Values are kept dequantized (f32) so aggregation on the server is a
/// straight [`Payload::add_into`]; the wire cost is tracked separately and
/// matches what [`crate::wire`] actually serializes.
#[derive(Clone, Debug)]
pub enum Payload {
    /// No compression: d * 32 bits.
    Dense(Vec<f32>),
    /// index/value pairs over a length-d vector:
    /// `k * (32 + index_bits(d))` bits.
    Sparse { d: u32, idx: Vec<u32>, val: Vec<f32> },
    /// Element-wise quantized vector: `bits_per_elem * d + overhead` bits.
    /// `val` holds the dequantized values.
    Quantized {
        val: Vec<f32>,
        bits_per_elem: f64,
        overhead_bits: u64,
    },
}

impl Payload {
    /// Dense length (d).
    pub fn dim(&self) -> usize {
        match self {
            Payload::Dense(v) => v.len(),
            Payload::Sparse { d, .. } => *d as usize,
            Payload::Quantized { val, .. } => val.len(),
        }
    }

    /// Exact wire cost of the payload body in bits.
    pub fn wire_bits(&self) -> u64 {
        match self {
            Payload::Dense(v) => 32 * v.len() as u64,
            Payload::Sparse { d, idx, .. } => {
                idx.len() as u64 * (32 + index_bits(*d as usize))
            }
            Payload::Quantized { val, bits_per_elem, overhead_bits } => {
                (bits_per_elem * val.len() as f64).ceil() as u64 + overhead_bits
            }
        }
    }

    /// Dense reconstruction.
    pub fn decode(&self) -> Vec<f32> {
        match self {
            Payload::Dense(v) => v.clone(),
            Payload::Sparse { d, idx, val } => {
                let mut out = vec![0.0; *d as usize];
                for (i, v) in idx.iter().zip(val) {
                    out[*i as usize] += *v;
                }
                out
            }
            Payload::Quantized { val, .. } => val.clone(),
        }
    }

    /// `acc += scale * decode(self)` without materializing the dense form.
    pub fn add_into(&self, acc: &mut [f32], scale: f32) {
        match self {
            Payload::Dense(v) | Payload::Quantized { val: v, .. } => {
                debug_assert_eq!(acc.len(), v.len());
                for (a, x) in acc.iter_mut().zip(v) {
                    *a += scale * x;
                }
            }
            Payload::Sparse { d, idx, val } => {
                debug_assert_eq!(acc.len(), *d as usize);
                for (i, x) in idx.iter().zip(val) {
                    acc[*i as usize] += scale * x;
                }
            }
        }
    }

    /// Multiply all carried values in place (used by the MLMC 1/p^l scale).
    pub fn scale_values(&mut self, s: f32) {
        match self {
            Payload::Dense(v) | Payload::Quantized { val: v, .. } => {
                for x in v {
                    *x *= s;
                }
            }
            Payload::Sparse { val, .. } => {
                for x in val {
                    *x *= s;
                }
            }
        }
    }
}

/// A compressed gradient: payload + fixed per-message overhead.
#[derive(Clone, Debug)]
pub struct Compressed {
    pub payload: Payload,
    /// header/metadata bits beyond the payload body (scales, levels, …)
    pub extra_bits: u64,
}

impl Compressed {
    pub fn dense(v: Vec<f32>) -> Self {
        Compressed { payload: Payload::Dense(v), extra_bits: 0 }
    }

    pub fn dim(&self) -> usize {
        self.payload.dim()
    }

    /// Total wire bits for this message.
    pub fn wire_bits(&self) -> u64 {
        self.payload.wire_bits() + self.extra_bits
    }

    pub fn decode(&self) -> Vec<f32> {
        self.payload.decode()
    }

    pub fn add_into(&self, acc: &mut [f32], scale: f32) {
        self.payload.add_into(acc, scale)
    }
}

/// A gradient compressor (paper Eq. (3)/(4)).
pub trait Compressor: Send + Sync {
    fn name(&self) -> String;
    /// Compress `v`. `rng` feeds any internal randomization.
    fn compress(&self, v: &[f32], rng: &mut Rng) -> Compressed;
    /// Whether `E[C(v)] = v` holds.
    fn unbiased(&self) -> bool;
}

/// The identity "compressor" (uncompressed SGD baseline).
#[derive(Clone, Debug, Default)]
pub struct Identity;

impl Compressor for Identity {
    fn name(&self) -> String {
        "sgd".into()
    }
    fn compress(&self, v: &[f32], _rng: &mut Rng) -> Compressed {
        Compressed::dense(v.to_vec())
    }
    fn unbiased(&self) -> bool {
        true
    }
}

/// Empirical compression statistics over random draws — used by the
/// lemma-validation harness ([`crate::figures::validate`]).
pub struct CompressionStats {
    /// `E‖C(v) − v‖² / ‖v‖²` (distortion; `1 − α` of Eq. (4))
    pub rel_distortion: f64,
    /// `‖E[C(v)] − v‖ / ‖v‖` (relative bias)
    pub rel_bias: f64,
    /// mean wire bits per message
    pub mean_bits: f64,
}

/// Estimate distortion/bias/cost of `c` on a fixed vector over `n` draws.
pub fn measure(c: &dyn Compressor, v: &[f32], n: usize, seed: u64) -> CompressionStats {
    let mut rng = Rng::new(seed);
    let d = v.len();
    let mut mean_est = vec![0.0f64; d];
    let mut dist = 0.0f64;
    let mut bits = 0.0f64;
    for _ in 0..n {
        let comp = c.compress(v, &mut rng);
        let dec = comp.decode();
        dist += crate::tensor::sq_dist(&dec, v);
        bits += comp.wire_bits() as f64;
        for (m, x) in mean_est.iter_mut().zip(&dec) {
            *m += *x as f64;
        }
    }
    let vn = crate::tensor::sq_norm(v).max(1e-30);
    let bias_sq: f64 = mean_est
        .iter()
        .zip(v)
        .map(|(m, x)| {
            let b = m / n as f64 - *x as f64;
            b * b
        })
        .sum();
    CompressionStats {
        rel_distortion: dist / n as f64 / vn,
        rel_bias: (bias_sq / vn).sqrt(),
        mean_bits: bits / n as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_bits_values() {
        assert_eq!(index_bits(2), 1);
        assert_eq!(index_bits(256), 8);
        assert_eq!(index_bits(257), 9);
        assert_eq!(index_bits(1_000_000), 20);
    }

    #[test]
    fn payload_sparse_roundtrip() {
        let p = Payload::Sparse { d: 5, idx: vec![1, 4], val: vec![2.0, -3.0] };
        assert_eq!(p.decode(), vec![0.0, 2.0, 0.0, 0.0, -3.0]);
        assert_eq!(p.wire_bits(), 2 * (32 + 3));
        let mut acc = vec![1.0; 5];
        p.add_into(&mut acc, 2.0);
        assert_eq!(acc, vec![1.0, 5.0, 1.0, 1.0, -5.0]);
    }

    #[test]
    fn payload_scale_values() {
        let mut p = Payload::Sparse { d: 3, idx: vec![0], val: vec![2.0] };
        p.scale_values(0.5);
        assert_eq!(p.decode(), vec![1.0, 0.0, 0.0]);
        let mut q = Payload::Quantized { val: vec![1.0, 2.0], bits_per_elem: 2.0, overhead_bits: 8 };
        q.scale_values(3.0);
        assert_eq!(q.decode(), vec![3.0, 6.0]);
        assert_eq!(q.wire_bits(), 4 + 8);
    }

    #[test]
    fn identity_exact() {
        let v = vec![1.0, -2.0, 3.0];
        let mut rng = Rng::new(0);
        let c = Identity.compress(&v, &mut rng);
        assert_eq!(c.decode(), v);
        assert_eq!(c.wire_bits(), 96);
        assert!(Identity.unbiased());
    }

    #[test]
    fn measure_identity_is_exact() {
        let v: Vec<f32> = (0..64).map(|i| (i as f32 - 32.0) / 7.0).collect();
        let s = measure(&Identity, &v, 10, 1);
        assert!(s.rel_distortion < 1e-12);
        assert!(s.rel_bias < 1e-7);
        assert_eq!(s.mean_bits, 64.0 * 32.0);
    }
}
