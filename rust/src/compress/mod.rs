//! Gradient compressor library (paper §2.2).
//!
//! Every compressor maps a flat gradient `v ∈ R^d` to a [`Compressed`]
//! payload carrying (a) enough information to reconstruct the dense
//! estimate and (b) its **exact wire cost in bits** — the x-axis of
//! Figs. 1/3/4/6. Unbiased compressors satisfy Eq. (3)
//! (`E[C(v)] = v`), biased ones Eq. (4)
//! (`E‖C(v)−v‖² ≤ (1−α)‖v‖²`).
//!
//! The MLMC wrapper that turns any *multilevel* biased compressor into an
//! unbiased one lives in [`crate::mlmc`].

pub mod arena;
pub mod bitwise;
pub mod natural;
pub mod par;
pub mod qsgd;
pub mod rtn;
pub mod sign;
pub mod sparsify;

pub use arena::ScratchArena;
pub use bitwise::{FixedPoint, FloatPoint};
pub use natural::Natural;
pub use par::ParCompressor;
pub use qsgd::Qsgd;
pub use rtn::Rtn;
pub use sign::SignSgd;
pub use sparsify::{RandK, STopK, TopK};

use crate::tensor::{kernels, Rng};

/// Bits to address one coordinate of a length-d vector.
pub fn index_bits(d: usize) -> u64 {
    (usize::BITS - d.max(2).saturating_sub(1).leading_zeros()) as u64
}

/// Abstract compressed payload.
///
/// Values are kept dequantized (f32) so aggregation on the server is a
/// straight [`Payload::add_into`]; the wire cost is tracked separately and
/// matches what [`crate::wire`] actually serializes.
#[derive(Clone, Debug)]
pub enum Payload {
    /// No compression: d * 32 bits.
    Dense(Vec<f32>),
    /// index/value pairs over a length-d vector:
    /// `k * (32 + index_bits(d))` bits.
    Sparse { d: u32, idx: Vec<u32>, val: Vec<f32> },
    /// Element-wise quantized vector: `bits_per_elem * d + overhead` bits.
    /// `val` holds the dequantized values.
    Quantized {
        val: Vec<f32>,
        bits_per_elem: f64,
        overhead_bits: u64,
    },
    /// Concatenation of independently compressed contiguous shards
    /// (the sharded pipeline — [`ParCompressor`]). Shard `i` covers the
    /// global index range `[Σ_{j<i} d_j, Σ_{j<=i} d_j)`. Framing
    /// overhead is accounted in the enclosing [`Compressed::extra_bits`]
    /// via [`shard_framing_bits`]; see [`Compressed::sharded`]. Shards
    /// must be flat payloads — nesting is not produced by any encoder
    /// and the wire decoder rejects nested sharded frames.
    Sharded(Vec<Payload>),
}

/// Accounted framing overhead of a sharded message — an accounting
/// *convention*, not a byte-exact transport size: one 32-bit shard
/// count plus a 32-bit per-shard allowance for the shard's
/// self-description. The transport ([`crate::wire`]) ships whatever
/// per-kind headers each shard needs (a Sparse shard carries d/k/len
/// fields, a Quantized shard its scale metadata); headers beyond this
/// allowance are excluded from accounting, exactly like the unsharded
/// convention where top-level kind/dim headers are never accounted.
pub fn shard_framing_bits(n_shards: usize) -> u64 {
    32 + 32 * n_shards as u64
}

impl Payload {
    /// Dense length (d).
    pub fn dim(&self) -> usize {
        match self {
            Payload::Dense(v) => v.len(),
            Payload::Sparse { d, .. } => *d as usize,
            Payload::Quantized { val, .. } => val.len(),
            Payload::Sharded(parts) => parts.iter().map(Payload::dim).sum(),
        }
    }

    /// Exact wire cost of the payload body in bits.
    pub fn wire_bits(&self) -> u64 {
        match self {
            Payload::Dense(v) => 32 * v.len() as u64,
            Payload::Sparse { d, idx, .. } => {
                idx.len() as u64 * (32 + index_bits(*d as usize))
            }
            Payload::Quantized { val, bits_per_elem, overhead_bits } => {
                (bits_per_elem * val.len() as f64).ceil() as u64 + overhead_bits
            }
            Payload::Sharded(parts) => parts.iter().map(Payload::wire_bits).sum(),
        }
    }

    /// Dense reconstruction.
    pub fn decode(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.dim());
        self.decode_append(&mut out);
        out
    }

    /// Dense reconstruction into a caller-owned buffer (cleared first) —
    /// the allocation-free form of [`Payload::decode`].
    pub fn decode_into(&self, out: &mut Vec<f32>) {
        out.clear();
        out.reserve(self.dim());
        self.decode_append(out);
    }

    fn decode_append(&self, out: &mut Vec<f32>) {
        match self {
            Payload::Dense(v) | Payload::Quantized { val: v, .. } => out.extend_from_slice(v),
            Payload::Sparse { d, idx, val } => {
                let lo = out.len();
                out.resize(lo + *d as usize, 0.0);
                kernels::scatter_add(&mut out[lo..], idx, val, 1.0);
            }
            Payload::Sharded(parts) => {
                for p in parts {
                    p.decode_append(out);
                }
            }
        }
    }

    /// `acc += scale * decode(self)` without materializing the dense form.
    pub fn add_into(&self, acc: &mut [f32], scale: f32) {
        match self {
            Payload::Dense(v) | Payload::Quantized { val: v, .. } => {
                kernels::axpy(acc, scale, v);
            }
            Payload::Sparse { d, idx, val } => {
                debug_assert_eq!(acc.len(), *d as usize);
                kernels::scatter_add(acc, idx, val, scale);
            }
            Payload::Sharded(parts) => {
                debug_assert_eq!(acc.len(), self.dim());
                let mut off = 0;
                for p in parts {
                    let pd = p.dim();
                    p.add_into(&mut acc[off..off + pd], scale);
                    off += pd;
                }
            }
        }
    }

    /// `acc += scale * decode(self)[start..start + acc.len()]` — the
    /// range-restricted form of [`Payload::add_into`] used by the
    /// sharded server reduction, where each thread owns a contiguous
    /// range of the accumulator. `acc` covers the payload's coordinates
    /// `[start, start + acc.len())`. Per coordinate, contributions are
    /// applied in exactly the order [`Payload::add_into`] applies them,
    /// so a range-partitioned reduction is bit-identical to the serial
    /// full-vector one.
    pub fn add_range_into(&self, acc: &mut [f32], scale: f32, start: usize) {
        let end = start + acc.len();
        debug_assert!(end <= self.dim());
        match self {
            Payload::Dense(v) | Payload::Quantized { val: v, .. } => {
                kernels::axpy(acc, scale, &v[start..end]);
            }
            Payload::Sparse { idx, val, .. } => {
                for (i, x) in idx.iter().zip(val) {
                    let i = *i as usize;
                    if (start..end).contains(&i) {
                        acc[i - start] += scale * x;
                    }
                }
            }
            Payload::Sharded(parts) => {
                let mut off = 0;
                for p in parts {
                    let pd = p.dim();
                    let lo = off.max(start);
                    let hi = (off + pd).min(end);
                    if lo < hi {
                        p.add_range_into(&mut acc[lo - start..hi - start], scale, lo - off);
                    }
                    off += pd;
                }
            }
        }
    }

    /// Multiply all carried values in place (used by the MLMC 1/p^l scale).
    pub fn scale_values(&mut self, s: f32) {
        match self {
            Payload::Dense(v) | Payload::Quantized { val: v, .. } => kernels::scale(v, s),
            Payload::Sparse { val, .. } => kernels::scale(val, s),
            Payload::Sharded(parts) => {
                for p in parts {
                    p.scale_values(s);
                }
            }
        }
    }
}

/// A compressed gradient: payload + fixed per-message overhead.
#[derive(Clone, Debug)]
pub struct Compressed {
    pub payload: Payload,
    /// header/metadata bits beyond the payload body (scales, levels, …)
    pub extra_bits: u64,
}

impl Compressed {
    pub fn dense(v: Vec<f32>) -> Self {
        Compressed { payload: Payload::Dense(v), extra_bits: 0 }
    }

    /// Assemble per-shard messages into one framed multi-shard message:
    /// per-shard `extra_bits` are accumulated into the container's,
    /// plus the shard framing overhead ([`shard_framing_bits`]).
    pub fn sharded(parts: Vec<Compressed>) -> Self {
        let extra: u64 =
            parts.iter().map(|c| c.extra_bits).sum::<u64>() + shard_framing_bits(parts.len());
        Compressed {
            payload: Payload::Sharded(parts.into_iter().map(|c| c.payload).collect()),
            extra_bits: extra,
        }
    }

    pub fn dim(&self) -> usize {
        self.payload.dim()
    }

    /// Total wire bits for this message.
    pub fn wire_bits(&self) -> u64 {
        self.payload.wire_bits() + self.extra_bits
    }

    pub fn decode(&self) -> Vec<f32> {
        self.payload.decode()
    }

    /// [`Compressed::decode`] into a caller-owned buffer (cleared first).
    pub fn decode_into(&self, out: &mut Vec<f32>) {
        self.payload.decode_into(out)
    }

    pub fn add_into(&self, acc: &mut [f32], scale: f32) {
        self.payload.add_into(acc, scale)
    }
}

/// A gradient compressor (paper Eq. (3)/(4)).
pub trait Compressor: Send + Sync {
    fn name(&self) -> String;
    /// Compress `v`. `rng` feeds any internal randomization.
    fn compress(&self, v: &[f32], rng: &mut Rng) -> Compressed;
    /// Compress `v` drawing scratch/output buffers from `arena` instead
    /// of the heap. **Contract:** bit-identical result and identical
    /// `rng` consumption vs. [`Compressor::compress`] (prop-tested in
    /// `tests/prop_simd.rs`); the default falls back to the allocating
    /// form, so overriding is purely a performance choice.
    fn compress_with(&self, v: &[f32], rng: &mut Rng, arena: &mut ScratchArena) -> Compressed {
        let _ = arena;
        self.compress(v, rng)
    }
    /// Whether `E[C(v)] = v` holds.
    fn unbiased(&self) -> bool;
}

/// The identity "compressor" (uncompressed SGD baseline).
#[derive(Clone, Debug, Default)]
pub struct Identity;

impl Compressor for Identity {
    fn name(&self) -> String {
        "sgd".into()
    }
    fn compress(&self, v: &[f32], _rng: &mut Rng) -> Compressed {
        Compressed::dense(v.to_vec())
    }
    fn compress_with(&self, v: &[f32], _rng: &mut Rng, arena: &mut ScratchArena) -> Compressed {
        let mut buf = arena.take_f32(v.len());
        buf.extend_from_slice(v);
        Compressed::dense(buf)
    }
    fn unbiased(&self) -> bool {
        true
    }
}

/// Empirical compression statistics over random draws — used by the
/// lemma-validation harness ([`crate::figures::validate`]).
pub struct CompressionStats {
    /// `E‖C(v) − v‖² / ‖v‖²` (distortion; `1 − α` of Eq. (4))
    pub rel_distortion: f64,
    /// `‖E[C(v)] − v‖ / ‖v‖` (relative bias)
    pub rel_bias: f64,
    /// mean wire bits per message
    pub mean_bits: f64,
}

/// Estimate distortion/bias/cost of `c` on a fixed vector over `n` draws.
pub fn measure(c: &dyn Compressor, v: &[f32], n: usize, seed: u64) -> CompressionStats {
    let mut rng = Rng::new(seed);
    let d = v.len();
    let mut mean_est = vec![0.0f64; d];
    let mut dist = 0.0f64;
    let mut bits = 0.0f64;
    for _ in 0..n {
        let comp = c.compress(v, &mut rng);
        let dec = comp.decode();
        dist += crate::tensor::sq_dist(&dec, v);
        bits += comp.wire_bits() as f64;
        for (m, x) in mean_est.iter_mut().zip(&dec) {
            *m += *x as f64;
        }
    }
    let vn = crate::tensor::sq_norm(v).max(1e-30);
    let bias_sq: f64 = mean_est
        .iter()
        .zip(v)
        .map(|(m, x)| {
            let b = m / n as f64 - *x as f64;
            b * b
        })
        .sum();
    CompressionStats {
        rel_distortion: dist / n as f64 / vn,
        rel_bias: (bias_sq / vn).sqrt(),
        mean_bits: bits / n as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_bits_values() {
        assert_eq!(index_bits(2), 1);
        assert_eq!(index_bits(256), 8);
        assert_eq!(index_bits(257), 9);
        assert_eq!(index_bits(1_000_000), 20);
    }

    #[test]
    fn payload_sparse_roundtrip() {
        let p = Payload::Sparse { d: 5, idx: vec![1, 4], val: vec![2.0, -3.0] };
        assert_eq!(p.decode(), vec![0.0, 2.0, 0.0, 0.0, -3.0]);
        assert_eq!(p.wire_bits(), 2 * (32 + 3));
        let mut acc = vec![1.0; 5];
        p.add_into(&mut acc, 2.0);
        assert_eq!(acc, vec![1.0, 5.0, 1.0, 1.0, -5.0]);
    }

    #[test]
    fn payload_scale_values() {
        let mut p = Payload::Sparse { d: 3, idx: vec![0], val: vec![2.0] };
        p.scale_values(0.5);
        assert_eq!(p.decode(), vec![1.0, 0.0, 0.0]);
        let mut q =
            Payload::Quantized { val: vec![1.0, 2.0], bits_per_elem: 2.0, overhead_bits: 8 };
        q.scale_values(3.0);
        assert_eq!(q.decode(), vec![3.0, 6.0]);
        assert_eq!(q.wire_bits(), 4 + 8);
    }

    #[test]
    fn payload_sharded_concatenates() {
        let p = Payload::Sharded(vec![
            Payload::Dense(vec![1.0, 2.0]),
            Payload::Sparse { d: 3, idx: vec![2], val: vec![5.0] },
            Payload::Quantized { val: vec![-1.0], bits_per_elem: 4.0, overhead_bits: 8 },
        ]);
        assert_eq!(p.dim(), 6);
        assert_eq!(p.decode(), vec![1.0, 2.0, 0.0, 0.0, 5.0, -1.0]);
        assert_eq!(p.wire_bits(), 64 + (32 + 2) + (4 + 8));
        let mut acc = vec![0.0; 6];
        p.add_into(&mut acc, 2.0);
        assert_eq!(acc, vec![2.0, 4.0, 0.0, 0.0, 10.0, -2.0]);
        let mut q = p.clone();
        q.scale_values(0.5);
        assert_eq!(q.decode(), vec![0.5, 1.0, 0.0, 0.0, 2.5, -0.5]);
    }

    #[test]
    fn add_range_into_matches_add_into_on_every_split() {
        let p = Payload::Sharded(vec![
            Payload::Sparse { d: 4, idx: vec![0, 3], val: vec![1.0, -2.0] },
            Payload::Dense(vec![3.0, 4.0, 5.0]),
            Payload::Sparse { d: 2, idx: vec![1], val: vec![7.0] },
        ]);
        let d = p.dim();
        let mut want = vec![0.5; d];
        p.add_into(&mut want, 1.5);
        for chunk in 1..=d {
            let mut got = vec![0.5; d];
            let mut start = 0;
            while start < d {
                let end = (start + chunk).min(d);
                p.add_range_into(&mut got[start..end], 1.5, start);
                start = end;
            }
            assert_eq!(got, want, "chunk={chunk}");
        }
        // also exercise the flat variants through the range path
        for flat in [
            Payload::Dense(vec![1.0, -1.0, 2.0, 0.5, 9.0]),
            Payload::Sparse { d: 5, idx: vec![4, 0], val: vec![2.0, 3.0] },
        ] {
            let mut want = vec![0.0; 5];
            flat.add_into(&mut want, 2.0);
            let mut got = vec![0.0; 5];
            flat.add_range_into(&mut got[0..2], 2.0, 0);
            flat.add_range_into(&mut got[2..5], 2.0, 2);
            assert_eq!(got, want);
        }
    }

    #[test]
    fn compressed_sharded_accounting() {
        let parts = vec![
            Compressed { payload: Payload::Dense(vec![1.0, 2.0]), extra_bits: 3 },
            Compressed {
                payload: Payload::Sparse { d: 8, idx: vec![1], val: vec![4.0] },
                extra_bits: 5,
            },
        ];
        let part_bits: u64 = parts.iter().map(Compressed::wire_bits).sum();
        let c = Compressed::sharded(parts);
        assert_eq!(c.dim(), 10);
        assert_eq!(c.wire_bits(), part_bits + shard_framing_bits(2));
        assert_eq!(c.extra_bits, 3 + 5 + shard_framing_bits(2));
        // empty message is well-formed
        let e = Compressed::sharded(Vec::new());
        assert_eq!(e.dim(), 0);
        assert_eq!(e.wire_bits(), shard_framing_bits(0));
    }

    #[test]
    fn decode_into_matches_decode() {
        let p = Payload::Sharded(vec![
            Payload::Dense(vec![1.0, 2.0]),
            Payload::Sparse { d: 3, idx: vec![2, 0], val: vec![5.0, -1.0] },
            Payload::Quantized { val: vec![-1.0], bits_per_elem: 4.0, overhead_bits: 8 },
        ]);
        let mut out = vec![9.0f32; 2]; // stale content must be cleared
        p.decode_into(&mut out);
        assert_eq!(out, p.decode());
    }

    #[test]
    fn identity_compress_with_reuses_arena() {
        let v = vec![1.0f32, -2.0, 3.0];
        let mut rng = Rng::new(0);
        let mut arena = ScratchArena::new();
        let c = Identity.compress_with(&v, &mut rng, &mut arena);
        assert_eq!(c.decode(), v);
        assert_eq!(c.wire_bits(), 96);
        arena.recycle(c);
        let c2 = Identity.compress_with(&v, &mut rng, &mut arena);
        assert_eq!(c2.decode(), v);
    }

    #[test]
    fn identity_exact() {
        let v = vec![1.0, -2.0, 3.0];
        let mut rng = Rng::new(0);
        let c = Identity.compress(&v, &mut rng);
        assert_eq!(c.decode(), v);
        assert_eq!(c.wire_bits(), 96);
        assert!(Identity.unbiased());
    }

    #[test]
    fn measure_identity_is_exact() {
        let v: Vec<f32> = (0..64).map(|i| (i as f32 - 32.0) / 7.0).collect();
        let s = measure(&Identity, &v, 10, 1);
        assert!(s.rel_distortion < 1e-12);
        assert!(s.rel_bias < 1e-7);
        assert_eq!(s.mean_bits, 64.0 * 32.0);
    }
}
