//! Round-to-Nearest (RTN) structured quantization (paper §3.2, App. G.2).
//!
//! `C_RTN^l(v) = δ^l · clip(round(v/δ^l), −c, c)`. We use the *odd
//! symmetric* grid: `2^l − 1` codes (`c = 2^{l−1} − 1` integer grid
//! units, `δ^l = c_val / c`), which covers `[−c_val, c_val]` exactly and
//! keeps every clipped value on the grid — the paper's
//! `δ^l = 2c/(2^l − 1)` differs only in how the even/odd endpoint is
//! handled. Wire accounting charges `l` bits/element as in the paper.
//! Level 1 has a single code {0} and degenerates to the zero compressor
//! (the paper evaluates RTN at l ≥ 2 only).
//!
//! Rounding is **half-to-even** to match `jnp.round` in the L1 Pallas
//! kernel (`python/compile/kernels/rtn.py`).

use super::{Compressed, Compressor, Payload, ScratchArena};
use crate::tensor::{kernels, max_abs, Rng};

/// RTN at a fixed level, clip range taken from the vector max.
#[derive(Clone, Debug)]
pub struct Rtn {
    pub level: u32,
}

impl Rtn {
    /// Positive grid extent in integer units: `2^{l−1} − 1` (0 for l = 1).
    pub fn c_units(level: u32) -> f32 {
        if level <= 1 {
            0.0
        } else {
            ((1u64 << (level - 1)) - 1) as f32
        }
    }

    /// Grid spacing over value range `[-c_val, c_val]`.
    pub fn delta(level: u32, c_val: f32) -> f32 {
        c_val / Self::c_units(level).max(1.0)
    }

    /// Apply RTN at (level, c_val) to every element.
    pub fn apply(v: &[f32], level: u32, c_val: f32) -> Vec<f32> {
        let mut out = Vec::with_capacity(v.len());
        Self::apply_into(v, level, c_val, &mut out);
        out
    }

    /// [`Rtn::apply`] into a caller-owned buffer (cleared first), routed
    /// through the vectorized grid-projection kernel.
    pub fn apply_into(v: &[f32], level: u32, c_val: f32, out: &mut Vec<f32>) {
        out.clear();
        out.resize(v.len(), 0.0);
        let c_units = Self::c_units(level);
        if c_val == 0.0 || c_units == 0.0 {
            return; // degenerate grid: everything maps to 0
        }
        kernels::rtn_apply(out, v, Self::delta(level, c_val), c_units);
    }
}

impl Compressor for Rtn {
    fn name(&self) -> String {
        format!("rtn(l={})", self.level)
    }

    fn compress(&self, v: &[f32], rng: &mut Rng) -> Compressed {
        self.compress_with(v, rng, &mut ScratchArena::new())
    }

    fn compress_with(&self, v: &[f32], _rng: &mut Rng, arena: &mut ScratchArena) -> Compressed {
        let c_val = max_abs(v);
        let mut val = arena.take_f32(v.len());
        Self::apply_into(v, self.level, c_val, &mut val);
        Compressed {
            payload: Payload::Quantized {
                val,
                bits_per_elem: self.level as f64,
                overhead_bits: 32,
            },
            extra_bits: 0,
        }
    }

    fn unbiased(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    fn test_vec(d: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..d).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn rtn_error_half_delta_in_range() {
        let v = test_vec(512, 1);
        let c_val = max_abs(&v);
        for level in [2u32, 4, 8] {
            let dec = Rtn::apply(&v, level, c_val);
            let half = Rtn::delta(level, c_val) / 2.0;
            for (a, b) in dec.iter().zip(&v) {
                assert!((a - b).abs() <= half + 1e-6, "l={level}");
            }
        }
    }

    #[test]
    fn rtn_on_grid() {
        let v = test_vec(128, 2);
        let c_val = max_abs(&v);
        let dec = Rtn::apply(&v, 3, c_val);
        let d = Rtn::delta(3, c_val);
        for x in &dec {
            let units = x / d;
            assert!((units - units.round()).abs() < 1e-4);
            assert!(units.abs() <= Rtn::c_units(3) + 1e-4);
        }
    }

    #[test]
    fn rtn_level1_degenerates_to_zero() {
        let v = test_vec(16, 6);
        assert_eq!(Rtn::apply(&v, 1, max_abs(&v)), vec![0.0; 16]);
    }

    #[test]
    fn rtn_round_half_to_even_matches_pallas_oracle() {
        // mirrors python/tests/test_kernels.py::test_rtn_clip
        let v = [100.0f32, -100.0, 0.06, 0.05];
        let dec: Vec<f32> = v
            .iter()
            .map(|x| 0.1 * (x / 0.1).round_ties_even().clamp(-3.0, 3.0))
            .collect();
        assert!((dec[0] - 0.3).abs() < 1e-6);
        assert!((dec[1] + 0.3).abs() < 1e-6);
        assert!((dec[2] - 0.1).abs() < 1e-6);
        assert_eq!(dec[3], 0.0); // 0.5 rounds to even 0
    }

    #[test]
    fn rtn_finer_levels_nested_improvement() {
        let v = test_vec(256, 3);
        let c_val = max_abs(&v);
        let mut prev = f64::INFINITY;
        for level in [2u32, 4, 8, 16] {
            let dec = Rtn::apply(&v, level, c_val);
            let err = crate::tensor::sq_dist(&dec, &v);
            assert!(err <= prev + 1e-12, "level {level}: {err} > {prev}");
            prev = err;
        }
    }

    #[test]
    fn rtn_wire_cost_and_zero() {
        let v = test_vec(100, 4);
        let mut rng = Rng::new(0);
        let c = Rtn { level: 4 }.compress(&v, &mut rng);
        assert_eq!(c.wire_bits(), 4 * 100 + 32);
        assert_eq!(Rtn::apply(&[0.0; 5], 4, 0.0), vec![0.0; 5]);
    }
}
