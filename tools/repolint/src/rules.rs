//! The eight rules. Each works on the scanner's code/comment channels —
//! no AST — so the banned shapes are *token* shapes, chosen to be
//! reliable under that constraint (see README §"Static analysis &
//! sanitizers" for the catalog and the rationale of each).

use crate::config::Config;
use crate::scan::{allow_target, directives, scan, Directive, Scanned};

#[derive(Debug)]
pub struct Diag {
    pub rule: &'static str,
    pub path: String,
    pub line: usize,
    pub col: usize,
    pub msg: String,
}

#[derive(Debug)]
pub struct AllowRec {
    pub rules: Vec<String>,
    pub path: String,
    pub line: usize,
    pub reason: String,
    pub used: bool,
}

pub struct FileLint {
    pub diags: Vec<Diag>,
    pub allows: Vec<AllowRec>,
    /// `unsafe` tokens in non-test code (ledger input)
    pub unsafe_count: usize,
    /// `(version_byte, layout_hash)` when the file carries frame markers
    pub frame: Option<(Option<u8>, u64)>,
}

pub const RULES: &[&str] = &[
    "wall_clock",
    "float_det",
    "hash_iter",
    "rng_discipline",
    "unsafe_ledger",
    "no_alloc_fence",
    "frame_pin",
    "panic_free_leader",
];

fn in_scope(path: &str, prefixes: &[String]) -> bool {
    prefixes.iter().any(|p| path.starts_with(p.as_str()))
}

const FLOAT_DET_BANNED: &[&str] = &[
    ".mul_add(",
    ".ln(",
    ".log(",
    ".log2(",
    ".log10(",
    ".exp(",
    ".exp2(",
    ".exp_m1(",
    ".ln_1p(",
    ".sin(",
    ".cos(",
    ".tan(",
    ".sin_cos(",
    ".asin(",
    ".acos(",
    ".atan(",
    ".atan2(",
    ".sinh(",
    ".cosh(",
    ".tanh(",
    ".powf(",
    "fmadd",
    "fnmadd",
];

const RNG_BANNED: &[&str] = &[
    "thread_rng",
    "from_entropy",
    "StdRng",
    "SmallRng",
    "OsRng",
    "getrandom",
    "rand::random",
    "RandomState",
];

const NO_ALLOC_BANNED: &[&str] = &["Vec::new", "vec!", ".to_vec(", "Box::new", ".collect("];

const PANIC_BANNED: &[&str] =
    &[".unwrap()", ".expect(", "panic!", "unreachable!", "todo!", "unimplemented!"];

/// First match of any needle in `hay`, as `(col, needle)`.
fn find_any<'a>(hay: &str, needles: &[&'a str]) -> Option<(usize, &'a str)> {
    let mut best: Option<(usize, &'a str)> = None;
    for n in needles {
        if let Some(p) = hay.find(n) {
            if best.is_none() || p < best.map(|(b, _)| b).unwrap_or(usize::MAX) {
                best = Some((p, n));
            }
        }
    }
    best
}

/// Is `code[pos]` the start of the word `word` (ident-boundary both
/// sides)?
fn word_at(code: &str, pos: usize, word: &str) -> bool {
    let b = code.as_bytes();
    let before_ok = pos == 0 || {
        let c = b[pos - 1] as char;
        !(c.is_alphanumeric() || c == '_')
    };
    let end = pos + word.len();
    let after_ok = end >= b.len() || {
        let c = b[end] as char;
        !(c.is_alphanumeric() || c == '_')
    };
    before_ok && after_ok
}

/// All ident-boundary occurrences of `word` in `code`.
fn word_positions(code: &str, word: &str) -> Vec<usize> {
    let mut out = Vec::new();
    let mut from = 0usize;
    while let Some(p) = code[from..].find(word) {
        let pos = from + p;
        if word_at(code, pos, word) {
            out.push(pos);
        }
        from = pos + word.len();
    }
    out
}

/// `[` positions that look like slice/array indexing: directly preceded
/// by an identifier char, `)` or `]`. Attributes (`#[`), array literals
/// and types (`= [`, `&[`, `: [`) don't match; macros (`vec![`) don't
/// match because `!` is not an identifier char.
fn index_positions(code: &str) -> Vec<usize> {
    let b = code.as_bytes();
    let mut out = Vec::new();
    for (p, &ch) in b.iter().enumerate() {
        if ch == b'[' && p > 0 {
            let prev = b[p - 1] as char;
            if prev.is_alphanumeric() || prev == '_' || prev == ')' || prev == ']' {
                out.push(p);
            }
        }
    }
    out
}

/// Does any comment within reach of line `idx` contain "safety" (ci)?
/// Reach = the same line, plus preceding lines that are blank, pure
/// comment, or attribute-only.
fn has_safety_comment(s: &Scanned, idx: usize) -> bool {
    let ci = |t: &str| t.to_ascii_lowercase().contains("safety");
    if ci(&s.lines[idx].comment) {
        return true;
    }
    let mut j = idx;
    while j > 0 {
        j -= 1;
        let code = s.lines[j].code.trim();
        let attached = code.is_empty() || code.starts_with("#[") || code.starts_with("#![");
        if !attached {
            return false;
        }
        if ci(&s.lines[j].comment) {
            return true;
        }
    }
    false
}

/// FNV-1a 64 (offset 0xcbf29ce484222325, prime 0x100000001b3).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Hash of the frame-layout region: code-channel lines between the
/// markers, rstripped, blanks dropped, joined with `\n`. Comment edits
/// and string contents don't move the hash; any code change does.
pub fn region_hash(s: &Scanned, start: usize, end: usize) -> u64 {
    let mut body = String::new();
    let mut first = true;
    for l in &s.lines[start + 1..end] {
        let t = l.code.trim_end();
        if t.is_empty() {
            continue;
        }
        if !first {
            body.push('\n');
        }
        body.push_str(t);
        first = false;
    }
    fnv1a64(body.as_bytes())
}

/// Lint one file's source text. `path` must be repo-relative with
/// forward slashes.
pub fn lint_source(path: &str, src: &str, cfg: &Config) -> FileLint {
    let s = scan(src);
    let mut diags: Vec<Diag> = Vec::new();
    let mut allows: Vec<AllowRec> = Vec::new();

    // (target_line, rule) -> allow index, for suppression lookup
    let mut allow_at: Vec<(usize, String, usize)> = Vec::new();
    let dirs = directives(&s.lines);
    let mut no_alloc_from: Option<usize> = None;
    let mut no_alloc_regions: Vec<(usize, usize)> = Vec::new();
    let mut frame_start: Option<usize> = None;
    let mut frame_end: Option<usize> = None;
    for (idx, d) in &dirs {
        match d {
            Directive::Allow { rules, reason } => {
                let target = allow_target(&s.lines, *idx);
                let rec = AllowRec {
                    rules: rules.clone(),
                    path: path.to_string(),
                    line: *idx + 1,
                    reason: reason.clone(),
                    used: false,
                };
                let ai = allows.len();
                for r in rules {
                    allow_at.push((target, r.clone(), ai));
                }
                allows.push(rec);
            }
            Directive::NoAllocStart => {
                if no_alloc_from.is_some() {
                    diags.push(Diag {
                        rule: "no_alloc_fence",
                        path: path.to_string(),
                        line: *idx + 1,
                        col: 0,
                        msg: "nested no_alloc(start)".to_string(),
                    });
                } else {
                    no_alloc_from = Some(*idx);
                }
            }
            Directive::NoAllocEnd => match no_alloc_from.take() {
                Some(from) => no_alloc_regions.push((from, *idx)),
                None => diags.push(Diag {
                    rule: "no_alloc_fence",
                    path: path.to_string(),
                    line: *idx + 1,
                    col: 0,
                    msg: "no_alloc(end) without a start".to_string(),
                }),
            },
            Directive::FrameStart => {
                if frame_start.is_some() {
                    diags.push(Diag {
                        rule: "frame_pin",
                        path: path.to_string(),
                        line: *idx + 1,
                        col: 0,
                        msg: "duplicate frame_layout(start)".to_string(),
                    });
                }
                frame_start = Some(*idx);
            }
            Directive::FrameEnd => frame_end = Some(*idx),
            Directive::Malformed(m) => diags.push(Diag {
                rule: "directive",
                path: path.to_string(),
                line: *idx + 1,
                col: 0,
                msg: m.clone(),
            }),
        }
    }
    if let Some(from) = no_alloc_from {
        diags.push(Diag {
            rule: "no_alloc_fence",
            path: path.to_string(),
            line: from + 1,
            col: 0,
            msg: "no_alloc(start) never closed".to_string(),
        });
    }

    // suppression-aware reporting: consult the allow table first
    #[allow(clippy::too_many_arguments)]
    fn fire(
        allow_at: &[(usize, String, usize)],
        allows: &mut [AllowRec],
        diags: &mut Vec<Diag>,
        path: &str,
        rule: &'static str,
        line0: usize,
        col: usize,
        msg: String,
    ) {
        for (target, r, ai) in allow_at {
            if *target == line0 && r == rule {
                allows[*ai].used = true;
                return;
            }
        }
        diags.push(Diag { rule, path: path.to_string(), line: line0 + 1, col, msg });
    }

    let wall_scoped = in_scope(path, &cfg.wall_clock_scope)
        && !in_scope(path, &cfg.wall_clock_exempt);
    let float_scoped = in_scope(path, &cfg.float_det_scope);
    let hash_scoped = in_scope(path, &cfg.hash_iter_scope);
    let rng_scoped = !in_scope(path, &cfg.rng_exempt);
    let panic_scoped = in_scope(path, &cfg.panic_free_scope);

    let mut unsafe_count = 0usize;

    for (i, l) in s.lines.iter().enumerate() {
        if s.in_test[i] {
            continue;
        }
        let code = l.code.as_str();

        if wall_scoped {
            if let Some((col, tok)) = find_any(code, &["Instant::now", "SystemTime::now"]) {
                fire(
                    &allow_at,
                    &mut allows,
                    &mut diags,
                    path,
                    "wall_clock",
                    i,
                    col,
                    format!("{tok} outside transport/bench scope breaks virtual-replay purity"),
                );
            }
        }
        if float_scoped {
            if let Some((col, tok)) = find_any(code, FLOAT_DET_BANNED) {
                fire(
                    &allow_at,
                    &mut allows,
                    &mut diags,
                    path,
                    "float_det",
                    i,
                    col,
                    format!(
                        "`{tok}` is not bit-deterministic across platforms; \
                         route through util::detmath or use an exact formulation"
                    ),
                );
            }
        }
        if hash_scoped {
            if let Some((col, tok)) = find_any(code, &["HashMap", "HashSet"]) {
                fire(
                    &allow_at,
                    &mut allows,
                    &mut diags,
                    path,
                    "hash_iter",
                    i,
                    col,
                    format!("{tok} iteration order is nondeterministic; use BTreeMap/sorted vecs"),
                );
            }
        }
        if rng_scoped {
            if let Some((col, tok)) = find_any(code, RNG_BANNED) {
                fire(
                    &allow_at,
                    &mut allows,
                    &mut diags,
                    path,
                    "rng_discipline",
                    i,
                    col,
                    format!("`{tok}`: entropy outside tensor/rng.rs seeded constructors"),
                );
            }
        }
        if panic_scoped {
            if let Some((col, tok)) = find_any(code, PANIC_BANNED) {
                fire(
                    &allow_at,
                    &mut allows,
                    &mut diags,
                    path,
                    "panic_free_leader",
                    i,
                    col,
                    format!("`{tok}` in a leader path: one bad frame must not kill the cluster"),
                );
            } else if let Some(col) = index_positions(code).first().copied() {
                fire(
                    &allow_at,
                    &mut allows,
                    &mut diags,
                    path,
                    "panic_free_leader",
                    i,
                    col,
                    "slice/array indexing in a leader path can panic; use .get()".to_string(),
                );
            }
        }
        for pos in word_positions(code, "unsafe") {
            unsafe_count += 1;
            if !has_safety_comment(&s, i) {
                fire(
                    &allow_at,
                    &mut allows,
                    &mut diags,
                    path,
                    "unsafe_ledger",
                    i,
                    pos,
                    "`unsafe` without a SAFETY comment (same line, preceding comment \
                     block, or `# Safety` doc)"
                        .to_string(),
                );
            }
        }
    }

    // no-alloc fenced regions
    for (from, to) in &no_alloc_regions {
        for i in (*from + 1)..*to {
            if s.in_test[i] {
                continue;
            }
            if let Some((col, tok)) = find_any(&s.lines[i].code, NO_ALLOC_BANNED) {
                fire(
                    &allow_at,
                    &mut allows,
                    &mut diags,
                    path,
                    "no_alloc_fence",
                    i,
                    col,
                    format!("`{tok}` inside a no_alloc fence (arena hot path must not allocate)"),
                );
            }
        }
    }

    // frame pin
    let mut frame: Option<(Option<u8>, u64)> = None;
    if path == cfg.frame_file {
        match (frame_start, frame_end) {
            (Some(a), Some(b)) if a < b => {
                let hash = region_hash(&s, a, b);
                let mut version: Option<u8> = None;
                for l in &s.lines[a + 1..b] {
                    if let Some(p) = l.code.find("ROUND_FRAME_VERSION") {
                        if let Some(h) = l.code[p..].find("0x") {
                            let hexpos = p + h + 2;
                            let hex: String = l.code[hexpos..]
                                .chars()
                                .take_while(|c| c.is_ascii_hexdigit())
                                .collect();
                            version = u8::from_str_radix(&hex, 16).ok();
                        }
                    }
                }
                frame = Some((version, hash));
                if version != Some(cfg.frame_version) {
                    diags.push(Diag {
                        rule: "frame_pin",
                        path: path.to_string(),
                        line: a + 1,
                        col: 0,
                        msg: format!(
                            "ROUND_FRAME_VERSION is {version:?}, config pins 0x{:02X}",
                            cfg.frame_version
                        ),
                    });
                } else if hash != cfg.frame_hash {
                    diags.push(Diag {
                        rule: "frame_pin",
                        path: path.to_string(),
                        line: a + 1,
                        col: 0,
                        msg: format!(
                            "frame layout region hash 0x{hash:016x} != pinned \
                             0x{:016x}: bump ROUND_FRAME_VERSION and re-pin \
                             (cargo run -p repolint -- --frame-hash)",
                            cfg.frame_hash
                        ),
                    });
                }
            }
            _ => diags.push(Diag {
                rule: "frame_pin",
                path: path.to_string(),
                line: 1,
                col: 0,
                msg: "frame_layout(start)/(end) markers missing or inverted".to_string(),
            }),
        }
    }

    // unused allows accrete silently — that defeats the inventory
    for a in allows.iter() {
        if !a.used {
            diags.push(Diag {
                rule: "directive",
                path: path.to_string(),
                line: a.line,
                col: 0,
                msg: format!("allow({}) suppresses nothing; remove it", a.rules.join(", ")),
            });
        }
    }

    FileLint { diags, allows, unsafe_count, frame }
}
