//! The repo's lint configuration, pinned as plain Rust constants (the
//! crate is std-only, so the config is code, not TOML — and a config
//! change is a reviewable source diff in the same commit as the change
//! that needed it).

/// Directories walked for `.rs` files (repo-relative, forward slashes).
/// `rust/vendor/` is deliberately absent: the vendored stubs are not
/// ours to lint.
pub const SCAN_ROOTS: &[&str] =
    &["rust/src", "rust/tests", "rust/benches", "examples", "tools/repolint/src"];

/// Everything the scanner finds in these trees is linted; the per-rule
/// scopes below narrow where each rule applies.
pub struct Config {
    /// wall_clock: `Instant::now` / `SystemTime::now` banned under this
    /// prefix...
    pub wall_clock_scope: Vec<String>,
    /// ...except these prefixes (real-time transport + bench harness)
    pub wall_clock_exempt: Vec<String>,
    /// float_det: transcendental / FMA calls banned under these prefixes
    pub float_det_scope: Vec<String>,
    /// hash_iter: `HashMap`/`HashSet` banned under these prefixes
    pub hash_iter_scope: Vec<String>,
    /// rng_discipline: entropy-source tokens banned everywhere except
    /// these prefixes (the seeded-constructor home)
    pub rng_exempt: Vec<String>,
    /// panic_free_leader: panics and indexing banned in these files
    pub panic_free_scope: Vec<String>,
    /// unsafe_ledger: exact expected `unsafe` token count per file; any
    /// file with unsafe code must be listed here with its exact count
    pub unsafe_ledger: Vec<(String, usize)>,
    /// frame_pin: the file carrying the pinned wire-layout region
    pub frame_file: String,
    /// frame_pin: expected `ROUND_FRAME_VERSION` byte
    pub frame_version: u8,
    /// frame_pin: expected FNV-1a-64 of the layout region's code channel
    /// (lines rstripped, blanks dropped, joined with `\n`)
    pub frame_hash: u64,
}

fn strs(v: &[&str]) -> Vec<String> {
    v.iter().map(|s| s.to_string()).collect()
}

impl Config {
    /// The configuration for this repository. Update the ledger / frame
    /// pin here, in the same commit as the change that moves them.
    pub fn repo() -> Config {
        Config {
            wall_clock_scope: strs(&["rust/src/"]),
            wall_clock_exempt: strs(&["rust/src/transport/", "rust/src/benchlib"]),
            float_det_scope: strs(&[
                "rust/src/tensor/kernels.rs",
                "rust/src/compress/",
                "rust/src/netsim/",
            ]),
            hash_iter_scope: strs(&["rust/src/"]),
            rng_exempt: strs(&["rust/src/tensor/rng.rs"]),
            panic_free_scope: strs(&[
                "rust/src/transport/tcp.rs",
                "rust/src/coordinator/cluster.rs",
            ]),
            unsafe_ledger: vec![
                ("rust/src/tensor/kernels.rs".to_string(), 18),
                ("rust/src/transport/poll.rs".to_string(), 1),
                ("rust/tests/alloc_zero.rs".to_string(), 5),
            ],
            frame_file: "rust/src/engine/framing.rs".to_string(),
            frame_version: 0xA4,
            // recompute with `cargo run -p repolint -- --frame-hash`
            // after an intentional layout change, and bump the version
            frame_hash: 0x6699_916b_ab80_6e3c,
        }
    }
}
