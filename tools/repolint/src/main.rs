//! CLI: lint the repo, print human diagnostics, write
//! `results/LINT.json`, exit nonzero on any violation.
//!
//! Usage:
//!   cargo run -p repolint                  # lint from the repo root
//!   cargo run -p repolint -- --root DIR    # explicit root
//!   cargo run -p repolint -- --frame-hash  # print the current frame
//!                                          # layout hash (for re-pinning)

use std::path::PathBuf;
use std::process::ExitCode;

use repolint::config::Config;
use repolint::json::esc;
use repolint::{lint_tree, Report};

fn find_root() -> Option<PathBuf> {
    let mut d = std::env::current_dir().ok()?;
    loop {
        if d.join("ROADMAP.md").exists() && d.join("rust/src").is_dir() {
            return Some(d);
        }
        if !d.pop() {
            return None;
        }
    }
}

fn render_json(r: &Report) -> String {
    let mut s = String::new();
    s.push_str("{\n  \"schema\": 1,\n");
    s.push_str(&format!("  \"files_scanned\": {},\n", r.files_scanned));
    s.push_str("  \"violations\": [\n");
    for (i, d) in r.diags.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"rule\": {}, \"path\": {}, \"line\": {}, \"col\": {}, \"msg\": {}}}{}\n",
            esc(d.rule),
            esc(&d.path),
            d.line,
            d.col,
            esc(&d.msg),
            if i + 1 < r.diags.len() { "," } else { "" }
        ));
    }
    s.push_str("  ],\n  \"allows\": [\n");
    for (i, a) in r.allows.iter().enumerate() {
        let rules = a.rules.iter().map(|x| esc(x.as_str())).collect::<Vec<_>>().join(", ");
        s.push_str(&format!(
            "    {{\"path\": {}, \"line\": {}, \"rules\": [{}], \"reason\": {}}}{}\n",
            esc(&a.path),
            a.line,
            rules,
            esc(&a.reason),
            if i + 1 < r.allows.len() { "," } else { "" }
        ));
    }
    s.push_str("  ],\n  \"unsafe_ledger\": {\n");
    let n = r.unsafe_counts.len();
    for (i, (p, c)) in r.unsafe_counts.iter().enumerate() {
        s.push_str(&format!(
            "    {}: {}{}\n",
            esc(p),
            c,
            if i + 1 < n { "," } else { "" }
        ));
    }
    s.push_str("  },\n");
    match r.frame {
        Some((v, h)) => s.push_str(&format!(
            "  \"frame\": {{\"version\": {}, \"layout_hash\": {}}}\n",
            match v {
                Some(b) => esc(&format!("0x{b:02X}")),
                None => "null".to_string(),
            },
            esc(&format!("0x{h:016x}"))
        )),
        None => s.push_str("  \"frame\": null\n"),
    }
    s.push('}');
    s.push('\n');
    s
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut root: Option<PathBuf> = None;
    let mut frame_hash_only = false;
    let mut i = 0usize;
    while let Some(a) = args.get(i) {
        match a.as_str() {
            "--root" => {
                root = args.get(i + 1).map(PathBuf::from);
                i += 2;
            }
            "--frame-hash" => {
                frame_hash_only = true;
                i += 1;
            }
            other => {
                eprintln!("repolint: unknown argument {other:?}");
                return ExitCode::from(2);
            }
        }
    }
    let Some(root) = root.or_else(find_root) else {
        eprintln!("repolint: could not locate the repo root (ROADMAP.md + rust/src)");
        return ExitCode::from(2);
    };
    let cfg = Config::repo();
    let report = lint_tree(&root, &cfg);

    if frame_hash_only {
        match report.frame {
            Some((v, h)) => {
                println!("frame version: {v:?}");
                println!("frame layout hash: 0x{h:016x}");
                return ExitCode::SUCCESS;
            }
            None => {
                eprintln!("repolint: no frame layout markers found");
                return ExitCode::from(2);
            }
        }
    }

    let results = root.join("results");
    let _ = std::fs::create_dir_all(&results);
    let json = render_json(&report);
    if let Err(e) = std::fs::write(results.join("LINT.json"), &json) {
        eprintln!("repolint: writing results/LINT.json failed: {e}");
    }

    for d in &report.diags {
        eprintln!("{}:{}:{}: [{}] {}", d.path, d.line, d.col + 1, d.rule, d.msg);
    }
    println!(
        "repolint: {} files, {} violation(s), {} inline allow(s)",
        report.files_scanned,
        report.diags.len(),
        report.allows.len()
    );
    if report.diags.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}
