//! Minimal JSON emission (std-only; the offline vendor set has no
//! serde). Only what LINT.json needs: escaped strings and hand-rolled
//! object/array assembly in the caller.

/// JSON string literal (quotes included).
pub fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}
