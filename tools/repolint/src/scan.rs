//! Line/token scanner: splits Rust source into parallel per-line `code`
//! and `comment` channels (columns preserved — every character lands in
//! exactly one channel, as a space in the other), with string and char
//! literal *contents* blanked out of the code channel so token searches
//! can never match inside a literal. Handles line comments, nested block
//! comments, normal/byte strings, raw strings (`r"…"`, `r#"…"#`, `br…`),
//! char literals vs lifetimes, and multi-line strings. No `syn`, no
//! dependencies — the scanner is the hermetic core the rules run on.

/// One scanned source line, all three views column-aligned.
pub struct Line {
    /// code text; comments, string contents and char-literal contents
    /// are spaces
    pub code: String,
    /// comment text (including the `//` / `/*` markers); code is spaces
    pub comment: String,
}

/// A scanned file.
pub struct Scanned {
    pub lines: Vec<Line>,
    /// per line: inside a `#[cfg(test)]`-gated item (brace-counted from
    /// the attribute)
    pub in_test: Vec<bool>,
}

#[derive(Clone, Copy, PartialEq)]
enum State {
    Code,
    LineComment,
    Block(u32),
    Str,
    RawStr(usize),
}

pub fn scan(src: &str) -> Scanned {
    let chars: Vec<char> = src.chars().collect();
    let mut lines: Vec<Line> = Vec::new();
    let mut code = String::new();
    let mut com = String::new();
    let mut state = State::Code;
    let mut i = 0usize;

    // channel pushers: c goes verbatim into one channel, a space into
    // the other, so columns stay aligned across channels
    fn push_code(code: &mut String, com: &mut String, c: char) {
        code.push(c);
        com.push(' ');
    }
    fn push_com(code: &mut String, com: &mut String, c: char) {
        code.push(' ');
        com.push(c);
    }
    // literal contents: blank in BOTH channels (not code, not comment)
    fn push_blank(code: &mut String, com: &mut String) {
        code.push(' ');
        com.push(' ');
    }

    while i < chars.len() {
        let c = chars[i];
        if c == '\n' {
            lines.push(Line { code: std::mem::take(&mut code), comment: std::mem::take(&mut com) });
            if state == State::LineComment {
                state = State::Code;
            }
            i += 1;
            continue;
        }
        match state {
            State::Code => {
                let next = chars.get(i + 1).copied().unwrap_or('\0');
                let prev_ident = i > 0 && {
                    let p = chars[i - 1];
                    p.is_alphanumeric() || p == '_'
                };
                if c == '/' && next == '/' {
                    push_com(&mut code, &mut com, '/');
                    push_com(&mut code, &mut com, '/');
                    state = State::LineComment;
                    i += 2;
                } else if c == '/' && next == '*' {
                    push_com(&mut code, &mut com, '/');
                    push_com(&mut code, &mut com, '*');
                    state = State::Block(1);
                    i += 2;
                } else if (c == 'r' || c == 'b') && !prev_ident {
                    // possible string prefix: r"…", r#"…, b"…", br#"…
                    let mut j = i + 1;
                    let mut is_raw = c == 'r';
                    if c == 'b' && chars.get(j) == Some(&'r') {
                        is_raw = true;
                        j += 1;
                    }
                    let mut hashes = 0usize;
                    if is_raw {
                        while chars.get(j) == Some(&'#') {
                            hashes += 1;
                            j += 1;
                        }
                    }
                    if chars.get(j) == Some(&'"') {
                        // blank the prefix and the opening quote
                        for _ in i..=j {
                            push_blank(&mut code, &mut com);
                        }
                        i = j + 1;
                        state = if is_raw { State::RawStr(hashes) } else { State::Str };
                    } else {
                        push_code(&mut code, &mut com, c);
                        i += 1;
                    }
                } else if c == '"' {
                    push_blank(&mut code, &mut com);
                    state = State::Str;
                    i += 1;
                } else if c == '\'' {
                    let n1 = chars.get(i + 1).copied();
                    if n1 == Some('\\') {
                        // escaped char literal: blank through the close
                        push_blank(&mut code, &mut com);
                        i += 1;
                        while i < chars.len() {
                            let d = chars[i];
                            if d == '\n' {
                                break; // malformed literal; don't eat the file
                            }
                            push_blank(&mut code, &mut com);
                            i += 1;
                            if d == '\\' {
                                if i < chars.len() && chars[i] != '\n' {
                                    push_blank(&mut code, &mut com);
                                    i += 1;
                                }
                                continue;
                            }
                            if d == '\'' {
                                break;
                            }
                        }
                    } else if n1.is_some() && chars.get(i + 2) == Some(&'\'') {
                        // plain 'x' char literal
                        push_blank(&mut code, &mut com);
                        push_blank(&mut code, &mut com);
                        push_blank(&mut code, &mut com);
                        i += 3;
                    } else {
                        // lifetime / loop label
                        push_code(&mut code, &mut com, '\'');
                        i += 1;
                    }
                } else {
                    push_code(&mut code, &mut com, c);
                    i += 1;
                }
            }
            State::LineComment => {
                push_com(&mut code, &mut com, c);
                i += 1;
            }
            State::Block(depth) => {
                if c == '*' && chars.get(i + 1) == Some(&'/') {
                    push_com(&mut code, &mut com, '*');
                    push_com(&mut code, &mut com, '/');
                    i += 2;
                    state = if depth <= 1 { State::Code } else { State::Block(depth - 1) };
                } else if c == '/' && chars.get(i + 1) == Some(&'*') {
                    push_com(&mut code, &mut com, '/');
                    push_com(&mut code, &mut com, '*');
                    i += 2;
                    state = State::Block(depth + 1);
                } else {
                    push_com(&mut code, &mut com, c);
                    i += 1;
                }
            }
            State::Str => {
                if c == '\\' {
                    push_blank(&mut code, &mut com);
                    i += 1;
                    if i < chars.len() && chars[i] != '\n' {
                        push_blank(&mut code, &mut com);
                        i += 1;
                    }
                } else {
                    push_blank(&mut code, &mut com);
                    i += 1;
                    if c == '"' {
                        state = State::Code;
                    }
                }
            }
            State::RawStr(hashes) => {
                if c == '"' {
                    let closed = (0..hashes).all(|k| chars.get(i + 1 + k) == Some(&'#'));
                    if closed {
                        for _ in 0..=hashes {
                            push_blank(&mut code, &mut com);
                        }
                        i += 1 + hashes;
                        state = State::Code;
                    } else {
                        push_blank(&mut code, &mut com);
                        i += 1;
                    }
                } else {
                    push_blank(&mut code, &mut com);
                    i += 1;
                }
            }
        }
    }
    if !code.is_empty() || !com.is_empty() {
        lines.push(Line { code, comment: com });
    }
    let in_test = mark_tests(&lines);
    Scanned { lines, in_test }
}

/// Mark every line belonging to a `#[cfg(test)]`-gated item by counting
/// braces in the code channel from the attribute onward.
fn mark_tests(lines: &[Line]) -> Vec<bool> {
    let mut in_test = vec![false; lines.len()];
    let mut i = 0usize;
    while i < lines.len() {
        let dense: String = lines[i].code.chars().filter(|c| !c.is_whitespace()).collect();
        if dense.contains("#[cfg(test)]") {
            let mut depth: i64 = 0;
            let mut seen_open = false;
            let mut j = i;
            while j < lines.len() {
                in_test[j] = true;
                for ch in lines[j].code.chars() {
                    if ch == '{' {
                        depth += 1;
                        seen_open = true;
                    } else if ch == '}' {
                        depth -= 1;
                    }
                }
                if seen_open && depth <= 0 {
                    break;
                }
                j += 1;
            }
            i = j + 1;
        } else {
            i += 1;
        }
    }
    in_test
}

/// A parsed repolint control comment.
pub enum Directive {
    /// suppress the named rules on the directive's target line; the
    /// reason is mandatory
    Allow { rules: Vec<String>, reason: String },
    NoAllocStart,
    NoAllocEnd,
    FrameStart,
    FrameEnd,
    Malformed(String),
}

const TAG: &str = "repolint:";

/// Extract directives from the comment channel. Returns `(line_index,
/// directive)` pairs in file order.
pub fn directives(lines: &[Line]) -> Vec<(usize, Directive)> {
    let mut out = Vec::new();
    for (idx, l) in lines.iter().enumerate() {
        let Some(p) = l.comment.find(TAG) else { continue };
        let rest = l.comment[p + TAG.len()..].trim();
        let d = if let Some(r) = rest.strip_prefix("allow(") {
            match r.find(')') {
                Some(close) => {
                    let rules: Vec<String> = r[..close]
                        .split(',')
                        .map(|s| s.trim().to_string())
                        .filter(|s| !s.is_empty())
                        .collect();
                    let tail = r[close + 1..].trim_start();
                    let reason = tail
                        .strip_prefix('—')
                        .or_else(|| tail.strip_prefix("--"))
                        .or_else(|| tail.strip_prefix('-'))
                        .map(str::trim)
                        .unwrap_or("");
                    if rules.is_empty() || reason.is_empty() {
                        Directive::Malformed(
                            "allow(...) needs a rule list and a `— reason`".to_string(),
                        )
                    } else {
                        Directive::Allow { rules, reason: reason.to_string() }
                    }
                }
                None => Directive::Malformed("unclosed allow(".to_string()),
            }
        } else if rest.starts_with("no_alloc(start)") {
            Directive::NoAllocStart
        } else if rest.starts_with("no_alloc(end)") {
            Directive::NoAllocEnd
        } else if rest.starts_with("frame_layout(start)") {
            Directive::FrameStart
        } else if rest.starts_with("frame_layout(end)") {
            Directive::FrameEnd
        } else {
            Directive::Malformed(format!(
                "unrecognized directive `{}`",
                rest.chars().take(40).collect::<String>()
            ))
        };
        out.push((idx, d));
    }
    out
}

/// The line an allow directive applies to: the directive's own line if
/// it carries code, else the next line with non-blank code (comment
/// continuation lines in between are skipped).
pub fn allow_target(lines: &[Line], idx: usize) -> usize {
    if !lines[idx].code.trim().is_empty() {
        return idx;
    }
    let mut j = idx + 1;
    while j < lines.len() {
        if !lines[j].code.trim().is_empty() {
            return j;
        }
        j += 1;
    }
    idx
}
