//! repolint — determinism/safety static analysis for the mlmc-dist
//! tree. Std-only and hermetic (no `syn`, no network deps): a
//! string/comment/attribute-aware line scanner plus eight token-level
//! rules that machine-check the invariants the property tests only
//! check at runtime (wall-clock purity, float determinism, hash-order
//! freedom, RNG discipline, the unsafe ledger, no-alloc fences, the
//! pinned frame layout, and the panic-free leader).
//!
//! See README §"Static analysis & sanitizers" for the rule catalog and
//! the inline-allow syntax.

pub mod config;
pub mod json;
pub mod rules;
pub mod scan;

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use config::{Config, SCAN_ROOTS};
use rules::{lint_source, AllowRec, Diag};

pub struct Report {
    pub diags: Vec<Diag>,
    pub allows: Vec<AllowRec>,
    /// actual non-test `unsafe` token counts, per file with any
    pub unsafe_counts: BTreeMap<String, usize>,
    /// `(version, hash)` extracted from the frame file, if found
    pub frame: Option<(Option<u8>, u64)>,
    pub files_scanned: usize,
}

fn walk(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(rd) = std::fs::read_dir(dir) else { return };
    let mut entries: Vec<PathBuf> = rd.flatten().map(|e| e.path()).collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            walk(&p, out);
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
}

/// Repo-relative forward-slash path.
fn rel(root: &Path, p: &Path) -> String {
    let s = p.strip_prefix(root).unwrap_or(p).to_string_lossy().to_string();
    s.replace('\\', "/")
}

/// Lint the whole tree rooted at `root` with the given config.
pub fn lint_tree(root: &Path, cfg: &Config) -> Report {
    let mut files: Vec<PathBuf> = Vec::new();
    for r in SCAN_ROOTS {
        walk(&root.join(r), &mut files);
    }
    files.sort();

    let mut diags: Vec<Diag> = Vec::new();
    let mut allows: Vec<AllowRec> = Vec::new();
    let mut unsafe_counts: BTreeMap<String, usize> = BTreeMap::new();
    let mut frame: Option<(Option<u8>, u64)> = None;
    let mut files_scanned = 0usize;

    for f in &files {
        let path = rel(root, f);
        let Ok(src) = std::fs::read_to_string(f) else {
            diags.push(Diag {
                rule: "io",
                path: path.clone(),
                line: 0,
                col: 0,
                msg: "unreadable file".to_string(),
            });
            continue;
        };
        files_scanned += 1;
        let mut fl = lint_source(&path, &src, cfg);
        diags.append(&mut fl.diags);
        allows.append(&mut fl.allows);
        if fl.unsafe_count > 0 {
            unsafe_counts.insert(path.clone(), fl.unsafe_count);
        }
        if fl.frame.is_some() {
            frame = fl.frame;
        }
    }

    // ledger reconciliation: every file with unsafe must be pinned at
    // its exact count, and every pinned file must still match
    let pinned: BTreeMap<&str, usize> =
        cfg.unsafe_ledger.iter().map(|(p, n)| (p.as_str(), *n)).collect();
    for (path, n) in &unsafe_counts {
        match pinned.get(path.as_str()) {
            Some(exp) if exp == n => {}
            Some(exp) => diags.push(Diag {
                rule: "unsafe_ledger",
                path: path.clone(),
                line: 0,
                col: 0,
                msg: format!(
                    "{n} unsafe tokens but the ledger pins {exp}: audit the \
                     change, then update unsafe_ledger in tools/repolint/src/config.rs"
                ),
            }),
            None => diags.push(Diag {
                rule: "unsafe_ledger",
                path: path.clone(),
                line: 0,
                col: 0,
                msg: format!(
                    "{n} unsafe tokens in a file the ledger does not list: new \
                     unsafe needs an audit + a ledger entry in tools/repolint/src/config.rs"
                ),
            }),
        }
    }
    for (path, exp) in &pinned {
        if !unsafe_counts.contains_key(*path) {
            diags.push(Diag {
                rule: "unsafe_ledger",
                path: path.to_string(),
                line: 0,
                col: 0,
                msg: format!(
                    "ledger pins {exp} unsafe tokens but the file has none \
                     (or is gone): drop the stale entry"
                ),
            });
        }
    }
    if frame.is_none() {
        diags.push(Diag {
            rule: "frame_pin",
            path: cfg.frame_file.clone(),
            line: 0,
            col: 0,
            msg: "frame file missing or its layout markers were never seen".to_string(),
        });
    }

    diags.sort_by(|a, b| (&a.path, a.line, a.rule).cmp(&(&b.path, b.line, b.rule)));
    allows.sort_by(|a, b| (&a.path, a.line).cmp(&(&b.path, b.line)));
    Report { diags, allows, unsafe_counts, frame, files_scanned }
}
