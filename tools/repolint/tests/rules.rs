//! Fixture corpus: every rule gets a known-bad snippet that must fire
//! (with the right rule name and line) and an allow-suppressed twin
//! that must stay quiet while landing in the allow inventory. The
//! fixtures live in string literals here precisely because this tests/
//! tree is outside repolint's own scan roots — directives in these
//! strings are data, not live suppressions.
//!
//! Fixtures are raw strings opening with a newline, so fixture line N
//! is source line N+1 (line 1 is the blank lead-in).

use repolint::config::Config;
use repolint::rules::{lint_source, FileLint};

/// A minimal config whose scopes are easy to hit from fixture paths.
fn cfg() -> Config {
    let strs = |v: &[&str]| v.iter().map(|s| s.to_string()).collect::<Vec<String>>();
    Config {
        wall_clock_scope: strs(&["src/"]),
        wall_clock_exempt: strs(&["src/transport/"]),
        float_det_scope: strs(&["src/"]),
        hash_iter_scope: strs(&["src/"]),
        rng_exempt: strs(&["src/rng.rs"]),
        panic_free_scope: strs(&["src/leader.rs"]),
        unsafe_ledger: Vec::new(),
        frame_file: "src/frame.rs".to_string(),
        frame_version: 0x01,
        frame_hash: 0,
    }
}

fn rules_fired(fl: &FileLint) -> Vec<&'static str> {
    fl.diags.iter().map(|d| d.rule).collect()
}

fn assert_clean_with_used_allow(fl: &FileLint, rule: &str) {
    assert!(fl.diags.is_empty(), "expected suppression, got {:?}", fl.diags);
    assert_eq!(fl.allows.len(), 1, "allow must land in the inventory");
    assert!(fl.allows[0].used, "allow must be marked used");
    assert!(fl.allows[0].rules.iter().any(|r| r == rule));
    assert!(!fl.allows[0].reason.is_empty(), "reason is mandatory");
}

// ---- rule 1: wall_clock ------------------------------------------------

const WALL_BAD: &str = r"
fn f() {
    let t = std::time::Instant::now();
    let _ = t;
}
";

#[test]
fn wall_clock_fires_and_names_the_line() {
    let fl = lint_source("src/a.rs", WALL_BAD, &cfg());
    assert_eq!(rules_fired(&fl), ["wall_clock"]);
    assert_eq!(fl.diags[0].line, 3);
}

#[test]
fn wall_clock_allow_suppresses_and_is_inventoried() {
    let src = r"
fn f() {
    // repolint: allow(wall_clock) -- fixture twin
    let t = std::time::Instant::now();
    let _ = t;
}
";
    let fl = lint_source("src/a.rs", src, &cfg());
    assert_clean_with_used_allow(&fl, "wall_clock");
}

#[test]
fn wall_clock_exempt_prefix_is_quiet() {
    let fl = lint_source("src/transport/t.rs", WALL_BAD, &cfg());
    assert!(fl.diags.is_empty());
}

// ---- rule 2: float_det -------------------------------------------------

#[test]
fn float_det_fires_on_powf() {
    let src = r"
fn f(x: f64) -> f64 {
    x.powf(2.0)
}
";
    let fl = lint_source("src/k.rs", src, &cfg());
    assert_eq!(rules_fired(&fl), ["float_det"]);
    assert_eq!(fl.diags[0].line, 3);
}

#[test]
fn float_det_allow_suppresses() {
    let src = r"
fn f(x: f64) -> f64 {
    // repolint: allow(float_det) -- fixture twin
    x.powf(2.0)
}
";
    let fl = lint_source("src/k.rs", src, &cfg());
    assert_clean_with_used_allow(&fl, "float_det");
}

// ---- rule 3: hash_iter -------------------------------------------------

#[test]
fn hash_iter_fires_on_hashmap() {
    let src = r"
use std::collections::HashMap;
fn f() {}
";
    let fl = lint_source("src/h.rs", src, &cfg());
    assert_eq!(rules_fired(&fl), ["hash_iter"]);
    assert_eq!(fl.diags[0].line, 2);
}

#[test]
fn hash_iter_allow_suppresses() {
    let src = r"
// repolint: allow(hash_iter) -- fixture twin
use std::collections::HashMap;
fn f() {}
";
    let fl = lint_source("src/h.rs", src, &cfg());
    assert_clean_with_used_allow(&fl, "hash_iter");
}

// ---- rule 4: rng_discipline --------------------------------------------

const RNG_BAD: &str = r"
fn f() {
    let r = rand::thread_rng();
    let _ = r;
}
";

#[test]
fn rng_discipline_fires_outside_the_rng_module() {
    let fl = lint_source("src/a.rs", RNG_BAD, &cfg());
    assert_eq!(rules_fired(&fl), ["rng_discipline"]);
    assert_eq!(fl.diags[0].line, 3);
}

#[test]
fn rng_discipline_quiet_in_the_rng_module() {
    let fl = lint_source("src/rng.rs", RNG_BAD, &cfg());
    assert!(fl.diags.is_empty());
}

#[test]
fn rng_discipline_allow_suppresses() {
    let src = r"
fn f() {
    // repolint: allow(rng_discipline) -- fixture twin
    let r = rand::thread_rng();
    let _ = r;
}
";
    let fl = lint_source("src/a.rs", src, &cfg());
    assert_clean_with_used_allow(&fl, "rng_discipline");
}

// ---- rule 5: unsafe_ledger ---------------------------------------------

#[test]
fn unsafe_without_safety_comment_fires() {
    let src = r"
fn f(p: *mut u8) {
    unsafe { *p = 0 };
}
";
    let fl = lint_source("src/u.rs", src, &cfg());
    assert_eq!(rules_fired(&fl), ["unsafe_ledger"]);
    assert_eq!(fl.diags[0].line, 3);
    assert_eq!(fl.unsafe_count, 1);
}

#[test]
fn unsafe_with_safety_comment_is_quiet_and_counted() {
    let src = r"
fn f(p: *mut u8) {
    // SAFETY: p is valid by contract
    unsafe { *p = 0 };
}
";
    let fl = lint_source("src/u.rs", src, &cfg());
    assert!(fl.diags.is_empty());
    assert_eq!(fl.unsafe_count, 1);
}

#[test]
fn unsafe_ledger_allow_suppresses() {
    let src = r"
fn f(p: *mut u8) {
    // repolint: allow(unsafe_ledger) -- fixture twin
    unsafe { *p = 0 };
}
";
    let fl = lint_source("src/u.rs", src, &cfg());
    assert_clean_with_used_allow(&fl, "unsafe_ledger");
    assert_eq!(fl.unsafe_count, 1);
}

// ---- rule 6: no_alloc_fence --------------------------------------------

#[test]
fn no_alloc_fence_fires_inside_the_region() {
    let src = r"
fn f() {
    // repolint: no_alloc(start) -- hot region
    let v: Vec<u32> = Vec::new();
    let _ = v;
    // repolint: no_alloc(end)
}
";
    let fl = lint_source("src/n.rs", src, &cfg());
    assert_eq!(rules_fired(&fl), ["no_alloc_fence"]);
    assert_eq!(fl.diags[0].line, 4);
}

#[test]
fn no_alloc_fence_quiet_outside_the_region() {
    let src = r"
fn f() {
    let v: Vec<u32> = Vec::new();
    let _ = v;
}
";
    let fl = lint_source("src/n.rs", src, &cfg());
    assert!(fl.diags.is_empty());
}

#[test]
fn no_alloc_fence_allow_suppresses() {
    let src = r"
fn f() {
    // repolint: no_alloc(start) -- hot region
    // repolint: allow(no_alloc_fence) -- fixture twin
    let v: Vec<u32> = Vec::new();
    let _ = v;
    // repolint: no_alloc(end)
}
";
    let fl = lint_source("src/n.rs", src, &cfg());
    assert_clean_with_used_allow(&fl, "no_alloc_fence");
}

#[test]
fn no_alloc_fence_unclosed_start_is_a_violation() {
    let src = r"
fn f() {
    // repolint: no_alloc(start) -- hot region
}
";
    let fl = lint_source("src/n.rs", src, &cfg());
    assert_eq!(rules_fired(&fl), ["no_alloc_fence"]);
}

// ---- rule 7: frame_pin -------------------------------------------------

const FRAME_SRC: &str = r"
// repolint: frame_layout(start) -- wire layout
pub const ROUND_FRAME_VERSION: u8 = 0x01;
pub struct Frame;
// repolint: frame_layout(end)
";

#[test]
fn frame_pin_fires_on_hash_mismatch() {
    // cfg() pins frame_hash = 0, which the region never hashes to
    let fl = lint_source("src/frame.rs", FRAME_SRC, &cfg());
    assert_eq!(rules_fired(&fl), ["frame_pin"]);
    let (version, hash) = fl.frame.expect("frame markers must be parsed");
    assert_eq!(version, Some(0x01));
    assert_ne!(hash, 0);
}

#[test]
fn frame_pin_quiet_when_correctly_pinned() {
    // the re-pin flow: read the hash off a first pass, pin it, re-lint
    let first = lint_source("src/frame.rs", FRAME_SRC, &cfg());
    let (_, hash) = first.frame.expect("frame markers must be parsed");
    let mut pinned = cfg();
    pinned.frame_hash = hash;
    let fl = lint_source("src/frame.rs", FRAME_SRC, &pinned);
    assert!(fl.diags.is_empty(), "got {:?}", fl.diags);
}

#[test]
fn frame_pin_fires_on_version_mismatch() {
    let first = lint_source("src/frame.rs", FRAME_SRC, &cfg());
    let (_, hash) = first.frame.expect("frame markers must be parsed");
    let mut pinned = cfg();
    pinned.frame_hash = hash;
    pinned.frame_version = 0x02;
    let fl = lint_source("src/frame.rs", FRAME_SRC, &pinned);
    assert_eq!(rules_fired(&fl), ["frame_pin"]);
}

#[test]
fn frame_pin_comment_edits_do_not_move_the_hash() {
    let reflowed = r"
// repolint: frame_layout(start) -- wire layout
// a new comment between the fields
pub const ROUND_FRAME_VERSION: u8 = 0x01; // trailing note
pub struct Frame;
// repolint: frame_layout(end)
";
    let a = lint_source("src/frame.rs", FRAME_SRC, &cfg());
    let b = lint_source("src/frame.rs", reflowed, &cfg());
    assert_eq!(a.frame.map(|f| f.1), b.frame.map(|f| f.1));
}

#[test]
fn frame_pin_code_edits_do_move_the_hash() {
    let changed = FRAME_SRC.replace("pub struct Frame;", "pub struct Frame(u8);");
    let a = lint_source("src/frame.rs", FRAME_SRC, &cfg());
    let b = lint_source("src/frame.rs", &changed, &cfg());
    assert_ne!(a.frame.map(|f| f.1), b.frame.map(|f| f.1));
}

// ---- rule 8: panic_free_leader -----------------------------------------

#[test]
fn panic_free_leader_fires_on_unwrap() {
    let src = r"
fn f(x: Option<u32>) -> u32 {
    x.unwrap()
}
";
    let fl = lint_source("src/leader.rs", src, &cfg());
    assert_eq!(rules_fired(&fl), ["panic_free_leader"]);
    assert_eq!(fl.diags[0].line, 3);
}

#[test]
fn panic_free_leader_fires_on_slice_indexing() {
    let src = r"
fn f(xs: &[u32]) -> u32 {
    xs[0]
}
";
    let fl = lint_source("src/leader.rs", src, &cfg());
    assert_eq!(rules_fired(&fl), ["panic_free_leader"]);
    assert_eq!(fl.diags[0].line, 3);
}

#[test]
fn panic_free_leader_does_not_flag_unwrap_or() {
    let src = r"
fn f(x: Option<u32>) -> u32 {
    x.unwrap_or(0)
}
";
    let fl = lint_source("src/leader.rs", src, &cfg());
    assert!(fl.diags.is_empty(), "got {:?}", fl.diags);
}

#[test]
fn panic_free_leader_allow_suppresses() {
    let src = r"
fn f(xs: &[u32]) -> u32 {
    // repolint: allow(panic_free_leader) -- fixture twin
    xs[0]
}
";
    let fl = lint_source("src/leader.rs", src, &cfg());
    assert_clean_with_used_allow(&fl, "panic_free_leader");
}

#[test]
fn panic_free_leader_out_of_scope_is_quiet() {
    let src = r"
fn f(x: Option<u32>) -> u32 {
    x.unwrap()
}
";
    let fl = lint_source("src/a.rs", src, &cfg());
    assert!(fl.diags.is_empty());
}

// ---- directive machinery ------------------------------------------------

#[test]
fn malformed_directive_is_a_violation() {
    let src = r"
// repolint: allom(whatever)
fn f() {}
";
    let fl = lint_source("src/d.rs", src, &cfg());
    assert_eq!(rules_fired(&fl), ["directive"]);
}

#[test]
fn allow_without_reason_is_malformed() {
    let src = r"
fn f() {
    // repolint: allow(wall_clock)
    let t = std::time::Instant::now();
    let _ = t;
}
";
    let fl = lint_source("src/d.rs", src, &cfg());
    // the allow never forms, so the wall_clock hit also survives
    let fired = rules_fired(&fl);
    assert!(fired.contains(&"directive"), "got {fired:?}");
    assert!(fired.contains(&"wall_clock"), "got {fired:?}");
}

#[test]
fn unused_allow_is_a_violation() {
    let src = r"
// repolint: allow(wall_clock) -- suppresses nothing here
fn f() {}
";
    let fl = lint_source("src/d.rs", src, &cfg());
    assert_eq!(rules_fired(&fl), ["directive"]);
    assert!(!fl.allows[0].used);
}

// ---- scanner discipline -------------------------------------------------

#[test]
fn banned_tokens_in_strings_and_comments_do_not_fire() {
    let src = r#"
fn f() -> &'static str {
    // Instant::now would be banned as code
    "Instant::now and HashMap live here"
}
"#;
    let fl = lint_source("src/s.rs", src, &cfg());
    assert!(fl.diags.is_empty(), "got {:?}", fl.diags);
}

#[test]
fn raw_strings_are_blanked() {
    let src = r##"
fn f() -> &'static str {
    r#"x.powf(2.0) .unwrap() HashMap"#
}
"##;
    let fl = lint_source("src/s.rs", src, &cfg());
    assert!(fl.diags.is_empty(), "got {:?}", fl.diags);
}

#[test]
fn cfg_test_modules_are_skipped() {
    let src = r"
fn prod() {}

#[cfg(test)]
mod tests {
    fn helper() {
        let t = std::time::Instant::now();
        let _ = t.elapsed();
    }
}
";
    let fl = lint_source("src/t.rs", src, &cfg());
    assert!(fl.diags.is_empty(), "got {:?}", fl.diags);
}
