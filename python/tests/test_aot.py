"""AOT metadata/artifact consistency: everything rust will load must exist
and match the declared shapes.
"""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot, model as M

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
META = os.path.join(ART, "metadata.json")

pytestmark = pytest.mark.skipif(
    not os.path.exists(META), reason="run `make artifacts` first"
)


def _meta():
    with open(META) as f:
        return json.load(f)


def test_all_artifact_files_exist():
    meta = _meta()
    assert len(meta["artifacts"]) >= 10
    for name, art in meta["artifacts"].items():
        path = os.path.join(ART, art["file"])
        assert os.path.exists(path), f"missing {path}"
        text = open(path).read()
        assert text.startswith("HloModule"), f"{name} is not HLO text"


def test_model_entries_reference_artifacts():
    meta = _meta()
    for mname, m in meta["models"].items():
        assert m["grad"] in meta["artifacts"]
        assert m["eval"] in meta["artifacts"]
        for art in m["segstats"].values():
            assert art in meta["artifacts"]
        assert m["param_count"] == M.param_count(mname)
        # param spec covers the whole vector contiguously
        off = 0
        for ps in m["params"]:
            assert ps["offset"] == off
            off += ps["numel"]
        assert off == m["param_count"]


def test_grad_artifact_io_shapes():
    meta = _meta()
    for mname, m in meta["models"].items():
        art = meta["artifacts"][m["grad"]]
        p = m["param_count"]
        assert art["inputs"][0] == {"dtype": "f32", "shape": [p]}
        # outputs: loss scalar + grad[p]
        assert art["outputs"][0]["shape"] == []
        assert art["outputs"][1] == {"dtype": "f32", "shape": [p]}


def test_segstats_artifact_io_shapes():
    meta = _meta()
    for mname, m in meta["models"].items():
        p = m["param_count"]
        for art_name in m["segstats"].values():
            art = meta["artifacts"][art_name]
            s, L = art["seg_size"], art["n_segs"]
            assert L == (p + s - 1) // s
            assert art["inputs"] == [{"dtype": "f32", "shape": [p]}]
            assert art["outputs"][0] == {"dtype": "f32", "shape": [L]}
            assert art["outputs"][1] == {"dtype": "i32", "shape": [p]}


def test_elementwise_artifacts():
    meta = _meta()
    n = meta["elemwise_chunk"]
    fx = meta["artifacts"][f"fx_truncate_c{n}"]
    assert fx["inputs"] == [
        {"dtype": "f32", "shape": [n]},
        {"dtype": "f32", "shape": [1]},
    ]
    rt = meta["artifacts"][f"rtn_c{n}"]
    assert len(rt["inputs"]) == 3


def test_seg_size_helper():
    assert aot.seg_size(100, 0.01) == 1
    assert aot.seg_size(118658, 0.5) == 59329
    assert aot.seg_size(3, 0.001) == 1  # never zero


def test_hlo_text_roundtrip_shape():
    """Lower a trivial fn and confirm to_hlo_text emits parseable HLO text."""
    def fn(x):
        return (x * 2.0,)

    lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct((4,), jnp.float32))
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "f32[4]" in text
