"""L2 model correctness: shapes, gradients (finite differences), training
signal, causal masking, and the seg_stats contract the rust layer depends on.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

jax.config.update("jax_platform_name", "cpu")


def _tx_setup(name="tx-tiny", seed=0):
    cfg = M.TX_CONFIGS[name]
    specs, p = M.tx_param_spec(cfg)
    flat = M.init_flat(specs, p, seed=seed)
    k = jax.random.PRNGKey(seed)
    x = jax.random.randint(k, (cfg.batch, cfg.seq_len), 0, cfg.vocab)
    if cfg.is_lm:
        y = jnp.roll(x, -1, axis=1)
    else:
        y = jax.random.randint(jax.random.PRNGKey(seed + 1), (cfg.batch,), 0, cfg.n_classes)
    return cfg, specs, p, flat, x, y


def test_param_layout_contiguous():
    for name in ("tx-tiny", "tx-small"):
        specs, total = M.tx_param_spec(M.TX_CONFIGS[name])
        off = 0
        for s in specs:
            assert s.offset == off
            off += s.numel
        assert off == total
    specs, total = M.cnn_param_spec(M.CNN_CONFIGS["cnn-tiny"])
    assert specs[-1].offset + specs[-1].numel == total


def test_tx_classifier_shapes():
    cfg, specs, p, flat, x, y = _tx_setup()
    logits = M.tx_forward(cfg, flat, x)
    assert logits.shape == (cfg.batch, cfg.n_classes)
    loss, grad = jax.jit(M.tx_grad_fn(cfg))(flat, x, y)
    assert loss.shape == () and grad.shape == (p,)
    assert bool(jnp.isfinite(loss)) and bool(jnp.all(jnp.isfinite(grad)))


def test_tx_grad_matches_finite_difference():
    cfg, specs, p, flat, x, y = _tx_setup()
    loss_fn = jax.jit(lambda fl: M.tx_loss(cfg, fl, x, y))
    g = jax.jit(jax.grad(lambda fl: M.tx_loss(cfg, fl, x, y)))(flat)
    rng = np.random.default_rng(0)
    idxs = rng.choice(p, size=8, replace=False)
    eps = 1e-3
    for i in idxs:
        e = jnp.zeros(p).at[i].set(eps)
        fd = (loss_fn(flat + e) - loss_fn(flat - e)) / (2 * eps)
        np.testing.assert_allclose(float(fd), float(g[i]), rtol=0.15, atol=5e-4)


def test_tx_sgd_reduces_loss():
    cfg, specs, p, flat, x, y = _tx_setup()
    grad_fn = jax.jit(M.tx_grad_fn(cfg))
    loss0, g = grad_fn(flat, x, y)
    for _ in range(20):
        _, g = grad_fn(flat, x, y)
        flat = flat - 0.5 * g
    loss1, _ = grad_fn(flat, x, y)
    assert float(loss1) < float(loss0)


def test_lm_causal_mask():
    """Changing a future token must not change the logits at earlier steps."""
    cfg, specs, p, flat, x, y = _tx_setup("lm-small")
    cfg_small = M.TxConfig("t", d_model=32, n_layers=2, n_heads=2, d_ff=64,
                           seq_len=16, batch=2)
    specs, p = M.tx_param_spec(cfg_small)
    flat = M.init_flat(specs, p)
    k = jax.random.PRNGKey(0)
    x = jax.random.randint(k, (2, 16), 0, 256)
    lg1 = M.tx_forward(cfg_small, flat, x)
    x2 = x.at[:, -1].set((x[:, -1] + 1) % 256)
    lg2 = M.tx_forward(cfg_small, flat, x2)
    np.testing.assert_allclose(np.asarray(lg1[:, :-1]), np.asarray(lg2[:, :-1]),
                               rtol=1e-5, atol=1e-5)


def test_eval_counts_bounded():
    cfg, specs, p, flat, x, y = _tx_setup()
    loss, nc = jax.jit(M.tx_eval_fn(cfg))(flat, x, y)
    assert 0 <= float(nc) <= cfg.batch


def test_cnn_shapes_and_grad():
    cfg = M.CNN_CONFIGS["cnn-tiny"]
    specs, p = M.cnn_param_spec(cfg)
    flat = M.init_flat(specs, p)
    k = jax.random.PRNGKey(0)
    x = jax.random.normal(k, (cfg.batch, 32, 32, 3))
    y = jax.random.randint(k, (cfg.batch,), 0, 10)
    loss, grad = jax.jit(M.cnn_grad_fn(cfg))(flat, x, y)
    assert grad.shape == (p,)
    assert abs(float(loss) - np.log(10)) < 1.0  # near-uniform at init
    # training signal
    for _ in range(15):
        _, g = jax.jit(M.cnn_grad_fn(cfg))(flat, x, y)
        flat = flat - 0.5 * g
    loss1, _ = jax.jit(M.cnn_grad_fn(cfg))(flat, x, y)
    assert float(loss1) < float(loss)


def test_cnn_eval():
    cfg = M.CNN_CONFIGS["cnn-tiny"]
    specs, p = M.cnn_param_spec(cfg)
    flat = M.init_flat(specs, p)
    k = jax.random.PRNGKey(1)
    x = jax.random.normal(k, (cfg.batch, 32, 32, 3))
    y = jax.random.randint(k, (cfg.batch,), 0, 10)
    loss, nc = jax.jit(M.cnn_eval_fn(cfg))(flat, x, y)
    assert 0 <= float(nc) <= cfg.batch


# --------------------------------------------------------------------------
# seg_stats: the contract consumed by rust/src/mlmc/adaptive.rs
# --------------------------------------------------------------------------


@pytest.mark.parametrize("d,s", [(1000, 10), (1000, 7), (128, 128), (128, 1), (37, 5)])
def test_seg_stats_contract(d, s):
    rng = np.random.default_rng(42)
    g = jnp.asarray(rng.normal(size=d).astype(np.float32))
    seg_sq, perm = jax.jit(M.seg_stats_fn(d, s))(g)
    n_segs = (d + s - 1) // s
    assert seg_sq.shape == (n_segs,)
    assert perm.shape == (d,)
    perm_np = np.asarray(perm)
    # perm is a permutation ordering |g| descending
    assert sorted(perm_np.tolist()) == list(range(d))
    a = np.abs(np.asarray(g))
    sorted_a = a[perm_np]
    assert np.all(np.diff(sorted_a) <= 1e-12)
    # seg_sq[l] equals the energy of segment l of the sorted vector
    padded = np.pad(sorted_a, (0, n_segs * s - d))
    want = np.sum(padded.reshape(n_segs, s) ** 2, axis=1)
    np.testing.assert_allclose(np.asarray(seg_sq), want, rtol=1e-5, atol=1e-7)
    # total energy is preserved: sum of seg energies == ||g||^2
    np.testing.assert_allclose(np.sum(np.asarray(seg_sq)), np.sum(a * a), rtol=1e-5)


def test_seg_stats_monotone_energy():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_t(3, size=4096).astype(np.float32))
    seg_sq, _ = jax.jit(M.seg_stats_fn(4096, 64))(g)
    assert np.all(np.diff(np.asarray(seg_sq)) <= 1e-6)
