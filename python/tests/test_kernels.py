"""L1 kernel correctness: Pallas (interpret=True) vs pure-jnp oracles.

hypothesis sweeps shapes, dtypes, and value ranges; explicit cases pin the
edge behaviours the rust layer relies on (zeros, sign handling, clipping).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import seg_energy, fx_truncate, rtn, ref, pad_rows

jax.config.update("jax_platform_name", "cpu")

F32 = np.float32


def _rand(shape, seed, lo=-4.0, hi=4.0, dtype=F32):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.uniform(lo, hi, size=shape).astype(dtype))


# --------------------------------------------------------------------------
# seg_energy
# --------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    rows_blocks=st.integers(1, 6),
    block_rows=st.sampled_from([1, 2, 4, 8]),
    s=st.integers(1, 67),
    seed=st.integers(0, 2**31 - 1),
)
def test_seg_energy_matches_ref(rows_blocks, block_rows, s, seed):
    rows = rows_blocks * block_rows
    mat = _rand((rows, s), seed)
    got = seg_energy(mat, block_rows=block_rows)
    want = ref.seg_energy_ref(mat)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_seg_energy_dtypes(dtype):
    mat = jnp.asarray(np.random.default_rng(0).normal(size=(8, 16)), dtype=dtype)
    got = seg_energy(mat)
    want = ref.seg_energy_ref(mat)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=tol, atol=tol)


def test_seg_energy_zero_rows_contribute_zero():
    mat = jnp.zeros((8, 4), jnp.float32)
    assert np.all(np.asarray(seg_energy(mat)) == 0.0)


def test_seg_energy_is_sq_norm():
    mat = _rand((8, 32), 7)
    got = np.asarray(seg_energy(mat))
    want = np.sum(np.asarray(mat) ** 2, axis=1)
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_pad_rows():
    mat = jnp.ones((5, 3))
    padded = pad_rows(mat, block_rows=4)
    assert padded.shape == (8, 3)
    assert np.all(np.asarray(padded[5:]) == 0)
    # already aligned: no-op
    assert pad_rows(jnp.ones((8, 3)), block_rows=4).shape == (8, 3)


def test_seg_energy_rejects_misaligned():
    with pytest.raises(ValueError):
        seg_energy(jnp.ones((7, 3)), block_rows=4)


# --------------------------------------------------------------------------
# fx_truncate
# --------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    nblocks=st.integers(1, 4),
    block=st.sampled_from([8, 64, 256]),
    level=st.integers(1, 30),
    seed=st.integers(0, 2**31 - 1),
)
def test_fx_truncate_matches_ref(nblocks, block, level, seed):
    x = _rand((nblocks * block,), seed, lo=-1.0, hi=1.0)
    pow2 = jnp.asarray([2.0**level], jnp.float32)
    got = fx_truncate(x, pow2, block=block)
    want = ref.fx_truncate_ref(x, pow2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0, atol=0)


def test_fx_truncate_distortion_bound():
    """|C^l(e) - e| <= 2^-l for normalized entries (paper section 3.1)."""
    x = _rand((4096,), 3, lo=-1.0, hi=1.0)
    for level in (1, 2, 5, 10, 20):
        pow2 = jnp.asarray([2.0**level], jnp.float32)
        got = np.asarray(fx_truncate(x, pow2))
        assert np.max(np.abs(got - np.asarray(x))) <= 2.0**-level + 1e-7


def test_fx_truncate_sign_and_zero():
    x = jnp.asarray([0.0, -0.75, 0.75, -1.0, 1.0], jnp.float32)
    pow2 = jnp.asarray([2.0], jnp.float32)  # level 1: keep one bit
    got = np.asarray(fx_truncate(x, pow2, block=5))
    np.testing.assert_array_equal(got, [0.0, -0.5, 0.5, -1.0, 1.0])


def test_fx_truncate_levels_nested():
    """Truncation to l bits then checking level l-1 prefix: residual is one bit."""
    x = _rand((1024,), 11, lo=-1.0, hi=1.0)
    for level in (2, 3, 8):
        hi = np.asarray(fx_truncate(x, jnp.asarray([2.0**level], jnp.float32)))
        lo = np.asarray(fx_truncate(x, jnp.asarray([2.0 ** (level - 1)], jnp.float32)))
        resid = np.abs(hi - lo)
        ok = np.isclose(resid, 0.0) | np.isclose(resid, 2.0**-level)
        assert ok.all()


# --------------------------------------------------------------------------
# rtn
# --------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    nblocks=st.integers(1, 4),
    block=st.sampled_from([8, 64, 256]),
    level=st.integers(1, 12),
    cval=st.floats(0.5, 4.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_rtn_matches_ref(nblocks, block, level, cval, seed):
    x = _rand((nblocks * block,), seed, lo=-2 * cval, hi=2 * cval)
    c_units = (2.0**level - 1) / 2.0
    delta = jnp.asarray([2.0 * cval / (2.0**level - 1)], jnp.float32)
    c = jnp.asarray([c_units], jnp.float32)
    got = rtn(x, delta, c, block=block)
    want = ref.rtn_ref(x, delta, c)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0, atol=0)


def test_rtn_clip():
    x = jnp.asarray([100.0, -100.0, 0.06, 0.05], jnp.float32)
    delta = jnp.asarray([0.1], jnp.float32)
    c = jnp.asarray([3.0], jnp.float32)
    got = np.asarray(rtn(x, delta, c, block=4))
    # note 0.05/0.1 = 0.5 rounds to 0: jnp.round is round-half-to-EVEN,
    # and rust's native RTN mirrors that with f32::round_ties_even.
    np.testing.assert_allclose(got, [0.3, -0.3, 0.1, 0.0], rtol=1e-6)


def test_rtn_quantization_error_half_delta():
    x = _rand((4096,), 5, lo=-0.9, hi=0.9)
    delta = jnp.asarray([0.25], jnp.float32)
    c = jnp.asarray([100.0], jnp.float32)  # no clipping in range
    got = np.asarray(rtn(x, delta, c))
    assert np.max(np.abs(got - np.asarray(x))) <= 0.125 + 1e-7
