"""Pallas kernel: blocked per-segment squared-norm reduction.

This is the L1 hot-spot of the adaptive MLMC path (Alg. 3): given the
magnitude-sorted gradient laid out as a (num_segments, s) matrix, compute
the squared l2-norm of every segment — the ``(Delta^l)^2`` table that
Lemma 3.4 turns into the optimal level distribution
``p^l ∝ Delta^l``.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the paper did this on
CUDA as a fused torch reduction; here each grid step streams a
(BLOCK_ROWS, s) tile HBM→VMEM via BlockSpec and reduces it on the VPU
(elementwise square + row sum — no MXU involvement). ``interpret=True``
everywhere because CPU-PJRT cannot execute Mosaic custom-calls.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows per grid step. 8 keeps the VMEM tile at 8*s floats; with the figure
# configs (s up to ~0.5M elements) a single row is already VMEM-sized, so
# the row-block is clamped at call time.
DEFAULT_BLOCK_ROWS = 8


def _kernel(x_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    o_ref[...] = jnp.sum(x * x, axis=1)


@functools.partial(jax.jit, static_argnames=("block_rows",))
def seg_energy(mat: jnp.ndarray, block_rows: int = DEFAULT_BLOCK_ROWS) -> jnp.ndarray:
    """Per-row sum of squares of a (rows, s) matrix via a Pallas reduction.

    ``rows`` must be a multiple of ``block_rows`` (callers pad with zero
    rows; zero rows contribute zero energy so padding is harmless).
    """
    rows, s = mat.shape
    br = min(block_rows, rows)
    if rows % br != 0:
        raise ValueError(f"rows={rows} not a multiple of block_rows={br}")
    grid = (rows // br,)
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((rows,), jnp.float32),
        grid=grid,
        in_specs=[pl.BlockSpec((br, s), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br,), lambda i: (i,)),
        interpret=True,
    )(mat)


def pad_rows(mat: jnp.ndarray, block_rows: int = DEFAULT_BLOCK_ROWS) -> jnp.ndarray:
    """Zero-pad the row dimension up to a multiple of ``block_rows``."""
    rows = mat.shape[0]
    br = min(block_rows, rows) if rows else block_rows
    rem = rows % br
    if rem == 0:
        return mat
    return jnp.pad(mat, ((0, br - rem), (0, 0)))
