"""Pallas kernel: element-wise round-to-nearest quantization (App. G.2).

``C_RTN^l(v) = delta^l * clip(round(v / delta^l), -c, c)`` with
``delta^l = 2*c_val/(2^l - 1)``. ``delta`` and ``c`` are runtime scalars so
one artifact serves every quantization level of the multilevel RTN
compressor — the structured-quantization example for which no
importance-sampling interpretation exists (paper §3.2).

TPU mapping: VPU elementwise; same 1-D HBM→VMEM tiling as fx_truncate.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 4096


def _kernel(x_ref, d_ref, c_ref, o_ref):
    x = x_ref[...]
    d = d_ref[0]
    c = c_ref[0]
    o_ref[...] = d * jnp.clip(jnp.round(x / d), -c, c)


@functools.partial(jax.jit, static_argnames=("block",))
def rtn(
    x: jnp.ndarray,
    delta: jnp.ndarray,
    c: jnp.ndarray,
    block: int = DEFAULT_BLOCK,
) -> jnp.ndarray:
    """RTN-quantize a 1-D vector on the grid (delta, clip c)."""
    (n,) = x.shape
    b = min(block, n)
    if n % b != 0:
        raise ValueError(f"n={n} not a multiple of block={b}")
    grid = (n // b,)
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((b,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((b,), lambda i: (i,)),
        interpret=True,
    )(x, delta, c)
