"""Pallas kernel: element-wise fixed-point truncation (paper §3.1).

Truncates each (max-normalized, |x|<=1) element to its first l fractional
bits: ``sign(x) * floor(|x| * 2^l) / 2^l``. The level enters as a runtime
``pow2 = 2^l`` scalar so one AOT artifact serves all 63 levels — the
multilevel compressor C^l of Definition 3.1 for the bit-wise family.

TPU mapping: pure VPU elementwise op; 1-D tiles of BLOCK elements stream
HBM→VMEM, the scalar rides along as a (1,)-block every grid step (on a
real TPU it would live in SMEM via PrefetchScalarGridSpec; interpret mode
has no SMEM distinction).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 4096


def _kernel(x_ref, p_ref, o_ref):
    x = x_ref[...]
    s = p_ref[0]
    o_ref[...] = jnp.sign(x) * jnp.floor(jnp.abs(x) * s) / s


@functools.partial(jax.jit, static_argnames=("block",))
def fx_truncate(x: jnp.ndarray, pow2: jnp.ndarray, block: int = DEFAULT_BLOCK) -> jnp.ndarray:
    """Fixed-point truncate a 1-D vector to the level encoded by ``pow2``.

    ``len(x)`` must be a multiple of ``block`` (callers pad; the padding
    values are truncated too and simply dropped on the host side).
    """
    (n,) = x.shape
    b = min(block, n)
    if n % b != 0:
        raise ValueError(f"n={n} not a multiple of block={b}")
    grid = (n // b,)
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((b,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((b,), lambda i: (i,)),
        interpret=True,
    )(x, pow2)
