"""Pure-jnp oracles for the Pallas kernels.

These are the correctness references: every Pallas kernel in this package
must match its oracle (up to dtype-appropriate tolerance) under pytest +
hypothesis sweeps (see python/tests/test_kernels.py).

The oracles also double as the semantic definition used by the rust layer:
rust's native implementations (rust/src/compress/) are validated against
vectors generated from these formulas in the integration tests.
"""

from __future__ import annotations

import jax.numpy as jnp


def seg_energy_ref(mat: jnp.ndarray) -> jnp.ndarray:
    """Row-wise sum of squares.

    ``mat`` has shape (num_segments, s): row l holds the l-th segment of
    the magnitude-sorted gradient. Returns shape (num_segments,) with
    ``out[l] = sum_j mat[l, j]**2`` — the (Delta^l)^2 table of Lemma 3.4.
    """
    m = mat.astype(jnp.float32)
    return jnp.sum(m * m, axis=1)


def fx_truncate_ref(x: jnp.ndarray, pow2: jnp.ndarray) -> jnp.ndarray:
    """Fixed-point truncation to level l (paper section 3.1).

    Keeps the first l fractional bits of |x| (assuming |x| <= 1 after
    normalization): ``sign(x) * floor(|x| * 2^l) / 2^l`` where
    ``pow2 = 2^l`` is passed as a runtime (1,)-shaped array so a single
    AOT artifact serves every level.
    """
    s = pow2.reshape(())
    return jnp.sign(x) * jnp.floor(jnp.abs(x) * s) / s


def rtn_ref(x: jnp.ndarray, delta: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    """Round-to-nearest quantization on a fixed grid (paper App. G.2).

    ``C_RTN(v) = delta * clip(round(v / delta), -c, c)`` with grid spacing
    ``delta = 2*c_val / (2^l - 1)`` chosen by the caller. ``delta`` and
    ``c`` (the clip bound, in grid units) are runtime (1,)-shaped arrays.
    """
    d = delta.reshape(())
    cc = c.reshape(())
    return d * jnp.clip(jnp.round(x / d), -cc, cc)
