"""L1 Pallas kernels (interpret=True) + pure-jnp oracles."""

from .seg_energy import seg_energy, pad_rows  # noqa: F401
from .fx_truncate import fx_truncate  # noqa: F401
from .rtn import rtn  # noqa: F401
from . import ref  # noqa: F401
