"""L2: JAX model definitions with *flat* parameter vectors.

Every model is a pure function of a single f32[P] parameter vector so the
rust coordinator owns exactly one buffer per model: the server aggregates
MLMC gradient estimates into a flat f32[P] and applies the optimizer to a
flat f32[P]. Unflattening happens inside the jitted graph with static
offsets (free at run time — XLA fuses the slices into the consumers).

Models:
  * ``TxConfig`` — byte-level pre-LN transformer; ``n_classes > 0`` gives a
    mean-pool sequence classifier (the GLUE-SST2 stand-in of Figs. 1/2/6),
    ``n_classes == 0`` gives a causal LM (the e2e training driver).
  * ``CnnConfig`` — small conv net on 32x32x3 images (the CIFAR-10/ResNet18
    stand-in of Figs. 3/4/5).

Alongside loss/grad/eval, ``seg_stats`` computes the adaptive-MLMC level
statistics of Lemma 3.4 — |g| sorted descending, segmented, per-segment
energies via the L1 Pallas kernel — plus the sort permutation so the rust
side can extract the sampled residual segment in O(s).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from .kernels.seg_energy import seg_energy, pad_rows

# --------------------------------------------------------------------------
# Parameter specs
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """One named tensor inside the flat parameter vector."""

    name: str
    shape: Tuple[int, ...]
    init: str  # "normal" | "zeros" | "ones"
    std: float = 0.0
    offset: int = 0  # filled in by `layout`

    @property
    def numel(self) -> int:
        return int(math.prod(self.shape))


def layout(specs: List[ParamSpec]) -> Tuple[List[ParamSpec], int]:
    """Assign offsets; return (specs, total parameter count)."""
    out, off = [], 0
    for s in specs:
        out.append(dataclasses.replace(s, offset=off))
        off += s.numel
    return out, off


def unflatten(flat: jnp.ndarray, specs: List[ParamSpec]) -> Dict[str, jnp.ndarray]:
    return {
        s.name: jax.lax.slice(flat, (s.offset,), (s.offset + s.numel,)).reshape(s.shape)
        for s in specs
    }


def init_flat(specs: List[ParamSpec], total: int, seed: int = 0) -> jnp.ndarray:
    """Python-side init (tests / parity checks; rust re-implements this spec)."""
    key = jax.random.PRNGKey(seed)
    parts = []
    for s in specs:
        key, sub = jax.random.split(key)
        if s.init == "normal":
            parts.append(jax.random.normal(sub, s.shape, jnp.float32).reshape(-1) * s.std)
        elif s.init == "ones":
            parts.append(jnp.ones(s.numel, jnp.float32))
        else:
            parts.append(jnp.zeros(s.numel, jnp.float32))
    flat = jnp.concatenate(parts)
    assert flat.shape == (total,)
    return flat


# --------------------------------------------------------------------------
# Transformer
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TxConfig:
    name: str
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    seq_len: int
    batch: int
    vocab: int = 256
    n_classes: int = 0  # 0 => causal LM

    @property
    def is_lm(self) -> bool:
        return self.n_classes == 0


def tx_param_spec(cfg: TxConfig) -> Tuple[List[ParamSpec], int]:
    d, f = cfg.d_model, cfg.d_ff
    std = 0.02
    out_std = std / math.sqrt(2.0 * cfg.n_layers)
    specs = [
        ParamSpec("tok_emb", (cfg.vocab, d), "normal", std),
        ParamSpec("pos_emb", (cfg.seq_len, d), "normal", std),
    ]
    for i in range(cfg.n_layers):
        p = f"l{i}."
        specs += [
            ParamSpec(p + "ln1_g", (d,), "ones"),
            ParamSpec(p + "ln1_b", (d,), "zeros"),
            ParamSpec(p + "wq", (d, d), "normal", std),
            ParamSpec(p + "wk", (d, d), "normal", std),
            ParamSpec(p + "wv", (d, d), "normal", std),
            ParamSpec(p + "wo", (d, d), "normal", out_std),
            ParamSpec(p + "bq", (d,), "zeros"),
            ParamSpec(p + "bk", (d,), "zeros"),
            ParamSpec(p + "bv", (d,), "zeros"),
            ParamSpec(p + "bo", (d,), "zeros"),
            ParamSpec(p + "ln2_g", (d,), "ones"),
            ParamSpec(p + "ln2_b", (d,), "zeros"),
            ParamSpec(p + "w1", (d, f), "normal", std),
            ParamSpec(p + "b1", (f,), "zeros"),
            ParamSpec(p + "w2", (f, d), "normal", out_std),
            ParamSpec(p + "b2", (d,), "zeros"),
        ]
    specs += [
        ParamSpec("lnf_g", (d,), "ones"),
        ParamSpec("lnf_b", (d,), "zeros"),
    ]
    head_out = cfg.vocab if cfg.is_lm else cfg.n_classes
    specs += [
        ParamSpec("head_w", (d, head_out), "normal", std),
        ParamSpec("head_b", (head_out,), "zeros"),
    ]
    return layout(specs)


def _layernorm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def _attention(x, p, prefix, cfg: TxConfig):
    b, s, d = x.shape
    h, dh = cfg.n_heads, cfg.d_model // cfg.n_heads
    q = (x @ p[prefix + "wq"] + p[prefix + "bq"]).reshape(b, s, h, dh)
    k = (x @ p[prefix + "wk"] + p[prefix + "bk"]).reshape(b, s, h, dh)
    v = (x @ p[prefix + "wv"] + p[prefix + "bv"]).reshape(b, s, h, dh)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / math.sqrt(dh)
    if cfg.is_lm:
        mask = jnp.tril(jnp.ones((s, s), jnp.bool_))
        scores = jnp.where(mask[None, None], scores, -1e30)
    att = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", att, v).reshape(b, s, d)
    return out @ p[prefix + "wo"] + p[prefix + "bo"]


def tx_forward(cfg: TxConfig, flat: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Return logits: (B, C) for classifier, (B, S, V) for LM."""
    specs, _ = tx_param_spec(cfg)
    p = unflatten(flat, specs)
    h = p["tok_emb"][x] + p["pos_emb"][None, : x.shape[1]]
    for i in range(cfg.n_layers):
        pre = f"l{i}."
        h = h + _attention(_layernorm(h, p[pre + "ln1_g"], p[pre + "ln1_b"]), p, pre, cfg)
        m = _layernorm(h, p[pre + "ln2_g"], p[pre + "ln2_b"])
        h = h + jax.nn.gelu(m @ p[pre + "w1"] + p[pre + "b1"]) @ p[pre + "w2"] + p[pre + "b2"]
    h = _layernorm(h, p["lnf_g"], p["lnf_b"])
    if cfg.is_lm:
        return h @ p["head_w"] + p["head_b"]
    pooled = jnp.mean(h, axis=1)
    return pooled @ p["head_w"] + p["head_b"]


def tx_loss(cfg: TxConfig, flat, x, y) -> jnp.ndarray:
    logits = tx_forward(cfg, flat, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    if cfg.is_lm:
        nll = -jnp.take_along_axis(logp, y[..., None], axis=-1)[..., 0]
    else:
        nll = -jnp.take_along_axis(logp, y[:, None], axis=-1)[:, 0]
    return jnp.mean(nll)


def tx_grad_fn(cfg: TxConfig):
    def f(flat, x, y):
        loss, grad = jax.value_and_grad(lambda fl: tx_loss(cfg, fl, x, y))(flat)
        return (loss, grad)

    return f


def tx_eval_fn(cfg: TxConfig):
    def f(flat, x, y):
        logits = tx_forward(cfg, flat, x)
        logp = jax.nn.log_softmax(logits, axis=-1)
        pred = jnp.argmax(logits, axis=-1)
        if cfg.is_lm:
            nll = -jnp.take_along_axis(logp, y[..., None], axis=-1)[..., 0]
        else:
            nll = -jnp.take_along_axis(logp, y[:, None], axis=-1)[:, 0]
        ncorrect = jnp.sum((pred == y).astype(jnp.float32))
        return (jnp.mean(nll), ncorrect)

    return f


# --------------------------------------------------------------------------
# CNN
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CnnConfig:
    name: str
    channels: Tuple[int, ...]
    batch: int
    image: int = 32
    in_channels: int = 3
    n_classes: int = 10


def cnn_param_spec(cfg: CnnConfig) -> Tuple[List[ParamSpec], int]:
    specs = []
    cin = cfg.in_channels
    for i, cout in enumerate(cfg.channels):
        he = math.sqrt(2.0 / (3 * 3 * cin))
        specs.append(ParamSpec(f"conv{i}_w", (3, 3, cin, cout), "normal", he))
        specs.append(ParamSpec(f"conv{i}_b", (cout,), "zeros"))
        cin = cout
    side = cfg.image // (2 ** len(cfg.channels))
    feat = side * side * cfg.channels[-1]
    specs.append(ParamSpec("fc_w", (feat, cfg.n_classes), "normal", math.sqrt(2.0 / feat)))
    specs.append(ParamSpec("fc_b", (cfg.n_classes,), "zeros"))
    return layout(specs)


def _avg_pool2(x):
    return jax.lax.reduce_window(
        x, 0.0, jax.lax.add, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    ) * 0.25


def cnn_forward(cfg: CnnConfig, flat: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    specs, _ = cnn_param_spec(cfg)
    p = unflatten(flat, specs)
    h = x  # NHWC
    for i in range(len(cfg.channels)):
        h = jax.lax.conv_general_dilated(
            h, p[f"conv{i}_w"], (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
        ) + p[f"conv{i}_b"]
        h = jax.nn.relu(h)
        h = _avg_pool2(h)
    h = h.reshape(h.shape[0], -1)
    return h @ p["fc_w"] + p["fc_b"]


def cnn_loss(cfg: CnnConfig, flat, x, y) -> jnp.ndarray:
    logits = cnn_forward(cfg, flat, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return jnp.mean(-jnp.take_along_axis(logp, y[:, None], axis=-1)[:, 0])


def cnn_grad_fn(cfg: CnnConfig):
    def f(flat, x, y):
        loss, grad = jax.value_and_grad(lambda fl: cnn_loss(cfg, fl, x, y))(flat)
        return (loss, grad)

    return f


def cnn_eval_fn(cfg: CnnConfig):
    def f(flat, x, y):
        logits = cnn_forward(cfg, flat, x)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, y[:, None], axis=-1)[:, 0]
        ncorrect = jnp.sum((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))
        return (jnp.mean(nll), ncorrect)

    return f


# --------------------------------------------------------------------------
# Adaptive-MLMC segment statistics (Lemma 3.4) via the L1 Pallas kernel
# --------------------------------------------------------------------------


def tx_grad_stats_fn(cfg: TxConfig, s: int):
    """Fused (params, x, y) -> (loss, grad, seg_sq, perm): the gradient
    step and the adaptive-MLMC statistics in ONE executable, so the rust
    hot path pays a single PJRT dispatch and never re-uploads the
    gradient (EXPERIMENTS.md §Perf)."""
    _, d = tx_param_spec(cfg)
    stats = seg_stats_fn(d, s)

    def f(flat, x, y):
        loss, grad = jax.value_and_grad(lambda fl: tx_loss(cfg, fl, x, y))(flat)
        seg_sq, perm = stats(grad)
        return (loss, grad, seg_sq, perm)

    return f


def cnn_grad_stats_fn(cfg: CnnConfig, s: int):
    """CNN variant of the fused grad+stats executable."""
    _, d = cnn_param_spec(cfg)
    stats = seg_stats_fn(d, s)

    def f(flat, x, y):
        loss, grad = jax.value_and_grad(lambda fl: cnn_loss(cfg, fl, x, y))(flat)
        seg_sq, perm = stats(grad)
        return (loss, grad, seg_sq, perm)

    return f


def seg_stats_fn(d: int, s: int):
    """Build the (grad[d]) -> (seg_sq[L], perm[d]) stats function.

    Sorts |g| descending (lax.sort_key_val so the permutation comes for
    free), zero-pads to L = ceil(d/s) full segments, and reduces each
    segment's energy with the Pallas kernel. ``seg_sq[l-1] = (Delta^l)^2``
    and ``perm[(l-1)*s : l*s]`` are the original indices of segment l.
    """
    n_segs = (d + s - 1) // s

    def f(grad: jnp.ndarray):
        a = jnp.abs(grad)
        iota = jax.lax.iota(jnp.int32, d)
        # ascending sort of -|g|  ==  descending sort of |g|
        _, perm = jax.lax.sort_key_val(-a, iota)
        svals = a[perm]
        pad = n_segs * s - d
        svals = jnp.pad(svals, (0, pad))
        mat = pad_rows(svals.reshape(n_segs, s))
        seg_sq = seg_energy(mat)[:n_segs]
        return (seg_sq, perm)

    return f


# --------------------------------------------------------------------------
# Model registry
# --------------------------------------------------------------------------

TX_CONFIGS = {
    # figure-scale classifier (SST2 stand-in, Figs. 1/2/6)
    "tx-tiny": TxConfig("tx-tiny", d_model=64, n_layers=2, n_heads=4, d_ff=256,
                        seq_len=32, batch=8, n_classes=2),
    # integration-scale classifier
    "tx-small": TxConfig("tx-small", d_model=128, n_layers=4, n_heads=4, d_ff=512,
                         seq_len=64, batch=8, n_classes=2),
    # e2e causal LMs
    "lm-small": TxConfig("lm-small", d_model=256, n_layers=4, n_heads=8, d_ff=1024,
                         seq_len=128, batch=8),
    "lm-med": TxConfig("lm-med", d_model=384, n_layers=6, n_heads=8, d_ff=1536,
                       seq_len=128, batch=8),
    # BERT-base-scale config (smoke-tested only on this single-core testbed)
    "lm-bert": TxConfig("lm-bert", d_model=768, n_layers=12, n_heads=12, d_ff=3072,
                        seq_len=128, batch=4),
}

CNN_CONFIGS = {
    # figure-scale CNN (CIFAR-10/ResNet18 stand-in, Figs. 3/4/5)
    "cnn-tiny": CnnConfig("cnn-tiny", channels=(8, 16, 32), batch=16),
    "cnn-small": CnnConfig("cnn-small", channels=(16, 32, 64), batch=32),
}


def param_count(name: str) -> int:
    if name in TX_CONFIGS:
        return tx_param_spec(TX_CONFIGS[name])[1]
    return cnn_param_spec(CNN_CONFIGS[name])[1]
