"""AOT bridge: lower every L2 function to HLO *text* + JSON metadata.

python runs exactly once (``make artifacts``); the rust coordinator loads
``artifacts/*.hlo.txt`` via ``HloModuleProto::from_text_file`` and never
touches python again.

HLO **text** (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which the crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage:  cd python && python -m compile.aot --out ../artifacts [--full]
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
from typing import Any, Dict, List

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels.fx_truncate import fx_truncate
from .kernels.rtn import rtn

ELEMWISE_CHUNK = 65536

# sparsification grids (fraction of the parameter count), per figure
TX_FRACS = [0.01, 0.05, 0.1, 0.5]  # Figs. 1/2
CNN_FRACS = [0.001, 0.005, 0.01, 0.05]  # Figs. 4/5
LM_FRACS = [0.01]  # e2e driver

DEFAULT_MODELS = ["tx-tiny", "tx-small", "cnn-tiny", "lm-small"]
FULL_MODELS = DEFAULT_MODELS + ["cnn-small", "lm-med", "lm-bert"]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(dtype, shape):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _io_meta(dtype, shape) -> Dict[str, Any]:
    name = {jnp.float32: "f32", jnp.int32: "i32"}[dtype]
    return {"dtype": name, "shape": list(shape)}


class Emitter:
    def __init__(self, out_dir: str, force: bool):
        self.out_dir = out_dir
        self.force = force
        self.artifacts: Dict[str, Any] = {}

    def emit(self, name: str, fn, inputs: List[Dict[str, Any]], extra: Dict[str, Any]):
        """Lower `fn` at the given input specs and write `<name>.hlo.txt`."""
        path = os.path.join(self.out_dir, f"{name}.hlo.txt")
        specs = [_spec({"f32": jnp.float32, "i32": jnp.int32}[i["dtype"]], i["shape"])
                 for i in inputs]
        abstract = jax.eval_shape(fn, *specs)
        outputs = [_io_meta(o.dtype.type if hasattr(o.dtype, "type") else o.dtype, o.shape)
                   for o in jax.tree_util.tree_leaves(abstract)]
        meta = {"file": os.path.basename(path), "inputs": inputs, "outputs": outputs}
        meta.update(extra)
        self.artifacts[name] = meta
        if os.path.exists(path) and not self.force:
            print(f"  [cached] {name}")
            return
        text = to_hlo_text(jax.jit(fn).lower(*specs))
        with open(path, "w") as f:
            f.write(text)
        print(f"  [lowered] {name} ({len(text)} chars)")


def _param_meta(specs: List[M.ParamSpec], total: int) -> List[Dict[str, Any]]:
    return [
        {
            "name": s.name,
            "shape": list(s.shape),
            "offset": s.offset,
            "numel": s.numel,
            "init": s.init,
            "std": s.std,
        }
        for s in specs
    ]


def seg_size(p: int, frac: float) -> int:
    return max(1, round(frac * p))


def emit_tx(em: Emitter, cfg: M.TxConfig, fracs: List[float], models_meta):
    specs, p = M.tx_param_spec(cfg)
    b, s = cfg.batch, cfg.seq_len
    y_shape = [b, s] if cfg.is_lm else [b]
    ins = [
        {"dtype": "f32", "shape": [p]},
        {"dtype": "i32", "shape": [b, s]},
        {"dtype": "i32", "shape": y_shape},
    ]
    base = {"model": cfg.name, "param_count": p}
    em.emit(f"{cfg.name}_grad", M.tx_grad_fn(cfg), ins, dict(base, kind="grad"))
    em.emit(f"{cfg.name}_eval", M.tx_eval_fn(cfg), ins, dict(base, kind="eval"))
    seg_artifacts = {}
    gradstats_artifacts = {}
    for frac in fracs:
        ssz = seg_size(p, frac)
        pm = round(frac * 1000)
        name = f"{cfg.name}_segstats_pm{pm}"
        em.emit(
            name,
            M.seg_stats_fn(p, ssz),
            [{"dtype": "f32", "shape": [p]}],
            dict(base, kind="segstats", seg_size=ssz, n_segs=(p + ssz - 1) // ssz,
                 frac_pm=pm),
        )
        seg_artifacts[str(pm)] = name
        # fused grad + stats: one dispatch on the Alg. 3 hot path
        gname = f"{cfg.name}_gradstats_pm{pm}"
        em.emit(
            gname,
            M.tx_grad_stats_fn(cfg, ssz),
            ins,
            dict(base, kind="gradstats", seg_size=ssz,
                 n_segs=(p + ssz - 1) // ssz, frac_pm=pm),
        )
        gradstats_artifacts[str(pm)] = gname
    models_meta[cfg.name] = {
        "kind": "lm" if cfg.is_lm else "tx",
        "param_count": p,
        "batch": b,
        "seq_len": s,
        "vocab": cfg.vocab,
        "n_classes": cfg.n_classes,
        "d_model": cfg.d_model,
        "n_layers": cfg.n_layers,
        "grad": f"{cfg.name}_grad",
        "eval": f"{cfg.name}_eval",
        "segstats": seg_artifacts,
        "gradstats": gradstats_artifacts,
        "params": _param_meta(specs, p),
    }


def emit_cnn(em: Emitter, cfg: M.CnnConfig, fracs: List[float], models_meta):
    specs, p = M.cnn_param_spec(cfg)
    b = cfg.batch
    ins = [
        {"dtype": "f32", "shape": [p]},
        {"dtype": "f32", "shape": [b, cfg.image, cfg.image, cfg.in_channels]},
        {"dtype": "i32", "shape": [b]},
    ]
    base = {"model": cfg.name, "param_count": p}
    em.emit(f"{cfg.name}_grad", M.cnn_grad_fn(cfg), ins, dict(base, kind="grad"))
    em.emit(f"{cfg.name}_eval", M.cnn_eval_fn(cfg), ins, dict(base, kind="eval"))
    seg_artifacts = {}
    gradstats_artifacts = {}
    for frac in fracs:
        ssz = seg_size(p, frac)
        pm = round(frac * 1000)
        name = f"{cfg.name}_segstats_pm{pm}"
        em.emit(
            name,
            M.seg_stats_fn(p, ssz),
            [{"dtype": "f32", "shape": [p]}],
            dict(base, kind="segstats", seg_size=ssz, n_segs=(p + ssz - 1) // ssz,
                 frac_pm=pm),
        )
        seg_artifacts[str(pm)] = name
        gname = f"{cfg.name}_gradstats_pm{pm}"
        em.emit(
            gname,
            M.cnn_grad_stats_fn(cfg, ssz),
            ins,
            dict(base, kind="gradstats", seg_size=ssz,
                 n_segs=(p + ssz - 1) // ssz, frac_pm=pm),
        )
        gradstats_artifacts[str(pm)] = gname
    models_meta[cfg.name] = {
        "kind": "cnn",
        "param_count": p,
        "batch": b,
        "image": cfg.image,
        "in_channels": cfg.in_channels,
        "n_classes": cfg.n_classes,
        "grad": f"{cfg.name}_grad",
        "eval": f"{cfg.name}_eval",
        "segstats": seg_artifacts,
        "gradstats": gradstats_artifacts,
        "params": _param_meta(specs, p),
    }


def emit_elementwise(em: Emitter):
    n = ELEMWISE_CHUNK

    def fx_fn(x, pow2):
        return (fx_truncate(x, pow2),)

    def rtn_fn(x, delta, c):
        return (rtn(x, delta, c),)

    em.emit(
        f"fx_truncate_c{n}",
        fx_fn,
        [{"dtype": "f32", "shape": [n]}, {"dtype": "f32", "shape": [1]}],
        {"kind": "elementwise", "chunk": n},
    )
    em.emit(
        f"rtn_c{n}",
        rtn_fn,
        [{"dtype": "f32", "shape": [n]}, {"dtype": "f32", "shape": [1]},
         {"dtype": "f32", "shape": [1]}],
        {"kind": "elementwise", "chunk": n},
    )


def emit_sanity(em: Emitter):
    """Tiny known-answer artifact for runtime smoke tests."""

    def fn(x, y):
        return (jnp.matmul(x, y) + 2.0,)

    em.emit(
        "sanity_matmul",
        fn,
        [{"dtype": "f32", "shape": [2, 2]}, {"dtype": "f32", "shape": [2, 2]}],
        {"kind": "sanity"},
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--full", action="store_true", help="also emit lm-med/lm-bert/cnn-small")
    ap.add_argument("--force", action="store_true", help="re-lower even if files exist")
    ap.add_argument("--models", nargs="*", default=None, help="explicit model list")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    em = Emitter(args.out, args.force)
    models_meta: Dict[str, Any] = {}

    names = args.models if args.models else (FULL_MODELS if args.full else DEFAULT_MODELS)
    print(f"AOT: emitting models {names} -> {args.out}")
    for name in names:
        if name in M.TX_CONFIGS:
            cfg = M.TX_CONFIGS[name]
            fracs = LM_FRACS if cfg.is_lm else TX_FRACS
            emit_tx(em, cfg, fracs, models_meta)
        elif name in M.CNN_CONFIGS:
            emit_cnn(em, M.CNN_CONFIGS[name], CNN_FRACS, models_meta)
        else:
            print(f"unknown model {name}", file=sys.stderr)
            sys.exit(1)
    emit_elementwise(em)
    emit_sanity(em)

    meta = {"elemwise_chunk": ELEMWISE_CHUNK, "models": models_meta,
            "artifacts": em.artifacts}
    meta_path = os.path.join(args.out, "metadata.json")
    # merge with an existing metadata.json so --models invocations extend it
    if os.path.exists(meta_path) and not args.force:
        with open(meta_path) as f:
            old = json.load(f)
        old_models = old.get("models", {})
        old_artifacts = old.get("artifacts", {})
        old_models.update(meta["models"])
        old_artifacts.update(meta["artifacts"])
        meta["models"], meta["artifacts"] = old_models, old_artifacts
    with open(meta_path, "w") as f:
        json.dump(meta, f, indent=1, sort_keys=True)
    print(f"wrote {meta_path}: {len(em.artifacts)} artifacts, {len(models_meta)} models")


if __name__ == "__main__":
    main()
